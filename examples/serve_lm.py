"""Serve a small model with batched requests through the cached decode path
(the same step function the decode_* dry-run cells lower at pod scale).

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma_2b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args(argv)

    from repro.launch.serve import main as serve_main

    serve_main(["--arch", args.arch, "--smoke",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--gen-len", str(args.gen_len)])


if __name__ == "__main__":
    main()
