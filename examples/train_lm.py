"""Train a language model end to end with the production trainer
(checkpointing, straggler monitor, resume) on the synthetic pipeline.

Smoke (CPU, ~1 min):
    PYTHONPATH=src python examples/train_lm.py

~100M-parameter run (a few hundred steps; sized for a single accelerator
host — on this CPU container it is compute-bound, so the default is smoke):
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config instead of the smoke config")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args(argv)

    from repro.launch.train import main as train_main

    argv2 = ["--arch", args.arch, "--steps", str(args.steps),
             "--checkpoint-every", str(max(args.steps // 3, 1)),
             "--resume", "auto", "--log-every", "10"]
    if args.full:
        # ~100M decoder: 12L x 768d via config surgery in-process
        import dataclasses
        from repro.configs import base as cb
        cfg = cb.get_config(args.arch)
        cfg100 = dataclasses.replace(
            cfg, name=cfg.name + "_100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, dtype="float32")
        cb.register(cfg100)
        argv2[1] = cfg100.name
        argv2 += ["--global-batch", "8", "--seq-len", "512"]
    else:
        argv2 += ["--smoke", "--global-batch", "4", "--seq-len", "128"]
    train_main(argv2)


if __name__ == "__main__":
    main()
