"""Quickstart: 60-second DMRG ground-state solve, validated against exact
diagonalization — the paper's algorithm end to end on the block-sparse
substrate.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import run_dmrg
from repro.core.ed import ground_energy
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space


def main():
    # 3x2 J1-J2 Heisenberg patch (the paper's "spins" system, small)
    space = spin_half_space()
    terms = heisenberg_j1j2_terms(3, 2, j1=1.0, j2=0.5, cylinder=False)
    n_sites = 6

    print("running two-site DMRG (list algorithm) ...")
    result = run_dmrg(
        space, terms, n_sites,
        bond_schedule=(8, 16), sweeps_per_bond=2, davidson_iters=6,
        verbose=True,
    )
    e_exact = ground_energy(space, terms, n_sites, charge=(0,))
    print(f"\nDMRG energy : {result.energy:.12f}")
    print(f"ED energy   : {e_exact:.12f}")
    print(f"|error|     : {abs(result.energy - e_exact):.2e}")
    assert abs(result.energy - e_exact) < 1e-8
    print("OK — DMRG matches exact diagonalization.")


if __name__ == "__main__":
    main()
