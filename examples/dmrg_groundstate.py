"""End-to-end driver for the paper's own workload: DMRG ground-state search
on the two benchmark systems (spins: 2D J1-J2 Heisenberg; electrons:
triangular Hubbard), with a growing bond-dimension schedule, per-sweep
energy/truncation logging, and a choice of the three contraction algorithms.

    PYTHONPATH=src python examples/dmrg_groundstate.py --system spins \
        --lx 4 --ly 2 --max-bond 32 --algo list
"""
import argparse
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--system", choices=["spins", "electrons"], default="spins")
    ap.add_argument("--lx", type=int, default=4)
    ap.add_argument("--ly", type=int, default=2)
    ap.add_argument("--max-bond", type=int, default=32)
    ap.add_argument("--sweeps-per-bond", type=int, default=2)
    ap.add_argument("--algo",
                    choices=["list", "dense", "csr", "csr_ref", "batched",
                             "auto", "list_unplanned"],
                    default="list")
    ap.add_argument("--jit-matvec", action="store_true",
                    help="jit the planned two-site matvec")
    ap.add_argument("--no-jit-env", action="store_true",
                    help="disable the fused jitted env updates (engine "
                         "algos default to them; bare algos always use the "
                         "seed extend path)")
    ap.add_argument("--svd-method",
                    choices=["svd", "randomized", "auto", "unplanned"],
                    default=None,
                    help="decomposition stage: planned batched SVD (default "
                         "for engine algos), randomized sketch, cost-model "
                         "auto, or the seed per-sector loop")
    ap.add_argument("--shard", action="store_true",
                    help="mesh-shard blocks over all visible devices "
                         "(pair with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8 on CPU)")
    ap.add_argument("--spmd", action="store_true",
                    help="true SPMD execution (docs/distributed.md): "
                         "device-resident envs + shard_map collective "
                         "bucket GEMMs over the (row, col) mesh; implies "
                         "the batched engine path")
    ap.add_argument("--j2", type=float, default=0.5)
    ap.add_argument("--u", type=float, default=8.5)
    ap.add_argument("--check-ed", action="store_true",
                    help="compare against exact diagonalization (small only)")
    ap.add_argument("--stats-json", metavar="PATH",
                    help="write run stats + global plan-cache counters as "
                         "JSON ('-' = stdout)")
    ap.add_argument("--checkpoint-dir", metavar="DIR",
                    help="persist sweep checkpoints here and resume from "
                         "the newest one on restart (README Robustness)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="site updates between mid-sweep checkpoints "
                         "(sweep boundaries always checkpoint)")
    ap.add_argument("--plan-store", metavar="DIR",
                    help="persistent plan + executable store (README Cold "
                         "start): a primed store takes the first sweep from "
                         "~20x steady-state cost to ~2x; a cold run primes it")
    args = ap.parse_args(argv)
    if args.algo.endswith("_unplanned") and (
        args.shard or args.spmd or args.jit_matvec
    ):
        ap.error("--shard/--spmd/--jit-matvec require an engine algo, "
                 "not " + args.algo)
    if args.shard and args.spmd:
        ap.error("--shard (storage mode) and --spmd are mutually exclusive")
    if args.algo.endswith("_unplanned") and args.svd_method not in (
        None, "unplanned",
    ):
        ap.error("--svd-method " + args.svd_method
                 + " requires an engine algo, not " + args.algo)

    from repro.core import run_dmrg
    from repro.core.models import electron_system, spin_system

    if args.system == "spins":
        space, terms = spin_system(args.lx, args.ly, j2=args.j2)
    else:
        space, terms = electron_system(args.lx, args.ly, u=args.u)
    n = args.lx * args.ly

    shard_policy = None
    if args.shard or args.spmd:
        from repro.dist import BlockShardPolicy, make_block_mesh
        shard_policy = BlockShardPolicy(
            make_block_mesh(), mode="spmd" if args.spmd else "auto"
        )

    schedule = [m for m in (8, 16, 32, 64, 128, 256) if m <= args.max_bond]
    print(f"{args.system}: {args.lx}x{args.ly} cylinder, {n} sites, "
          f"algo={'spmd' if args.spmd else args.algo}, schedule={schedule}"
          + (f", mesh={dict(shard_policy.mesh.shape)}" if shard_policy else ""))
    res = run_dmrg(space, terms, n, bond_schedule=schedule,
                   sweeps_per_bond=args.sweeps_per_bond,
                   davidson_iters=4, algo=args.algo, verbose=True,
                   jit_matvec=args.jit_matvec or args.spmd,
                   shard_policy=shard_policy, spmd=args.spmd,
                   svd_method=args.svd_method,
                   jit_env=False if args.no_jit_env
                   or args.algo.endswith("_unplanned") else None,
                   checkpoint_dir=args.checkpoint_dir,
                   checkpoint_every=args.checkpoint_every,
                   plan_store=args.plan_store)
    print(f"\nground-state energy estimate: {res.energy:.10f}")
    print(f"energy per site:              {res.energy / n:.10f}")

    if args.check_ed and n <= 12:
        from repro.core.ed import ground_energy
        from repro.core.mps import neel_states, total_charge
        q = total_charge(space, neel_states(space, n))
        e0 = ground_energy(space, terms, n, charge=q)
        print(f"ED reference:                 {e0:.10f} "
              f"(|err|={abs(res.energy - e0):.2e})")

    if args.stats_json:
        import json

        from repro.dist import cache_stats

        payload = {
            "energy": float(res.energy),
            "energy_per_site": float(res.energy) / n,
            "n_sites": n,
            "algo": args.algo,
            "schedule": schedule,
            "caches": cache_stats(),
        }
        if args.spmd:
            from repro.dist import spmd_stats

            payload["spmd"] = spmd_stats()
        text = json.dumps(payload, indent=2, default=str)
        if args.stats_json == "-":
            print(text)
        else:
            with open(args.stats_json, "w") as fh:
                fh.write(text + "\n")
            print(f"stats written to {args.stats_json}")


if __name__ == "__main__":
    main()
