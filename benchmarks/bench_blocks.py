"""Paper Fig. 2 analogue: MPS block structure vs bond dimension.

Reports, for the mid-chain MPS tensor of the spins system at growing m:
largest block share (their Fig. 2a: largest block ~ m^0.94 for spins),
number of blocks, and block-sparsity fraction (Fig. 2b).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.models import heisenberg_j1j2_terms, triangular_hubbard_terms
from repro.core.mpo import build_mpo, compress_mpo
from repro.core.mps import neel_states, product_state_mps
from repro.core.siteops import electron_space, spin_half_space
from repro.core.sweep import DMRGEngine


def stats_for(space, terms, n, m):
    mpo = compress_mpo(build_mpo(space, terms, n), cutoff=1e-13)
    mps = product_state_mps(space, neel_states(space, n))
    eng = DMRGEngine(mps, mpo, algo="list", davidson_iters=2)
    for mm in (8, 16, 32, 64, 128):
        if mm > m:
            break
        eng.sweep(max_bond=min(mm, m))
    t = eng.mps.tensors[n // 2]
    dims = [t.indices[0].sector_dim(s) for s in range(t.indices[0].num_sectors)]
    dense_elems = float(np.prod(t.shape))
    return dict(
        bond=t.indices[0].dim,
        n_blocks=t.num_blocks,
        largest_block=max(dims),
        sparsity=1.0 - t.nnz / dense_elems,
    )


def run(ms=(16, 32, 64)):
    rows = []
    sp = spin_half_space()
    terms_s = heisenberg_j1j2_terms(5, 2, 1.0, 0.5, cylinder=False)
    el = electron_space()
    terms_e = triangular_hubbard_terms(4, 2, 1.0, 8.5, cylinder=False)
    for m in ms:
        t0 = time.perf_counter()
        s = stats_for(sp, terms_s, 10, m)
        dt = time.perf_counter() - t0
        rows.append((f"blocks_spins_m{m}", dt * 1e6,
                     f"bond={s['bond']};blocks={s['n_blocks']};"
                     f"largest={s['largest_block']};sparsity={s['sparsity']:.3f}"))
    for m in ms[:2]:
        t0 = time.perf_counter()
        s = stats_for(el, terms_e, 8, m)
        dt = time.perf_counter() - t0
        rows.append((f"blocks_electrons_m{m}", dt * 1e6,
                     f"bond={s['bond']};blocks={s['n_blocks']};"
                     f"largest={s['largest_block']};sparsity={s['sparsity']:.3f}"))
    return rows
