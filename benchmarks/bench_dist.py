"""Distributed contraction engine: plan-cache and mesh-sharding benchmarks.

Weak-scaling style run on a 16-site m=32 Heisenberg chain comparing

- seed per-call contraction (``list_unplanned``) vs the plan-cached engine
  (``list``) vs the plan-cached + jitted planned matvec (``list`` + jit),
- an 8-fake-device mesh-sharded sweep (energy must match single-device),

and emits both CSV rows (via benchmarks/run.py) and a JSON record so future
PRs have a perf trajectory.  Must run in its own process with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set *before* jax
imports; ``main()`` below re-execs itself accordingly and run.py invokes it
as a subprocess.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_XLA_FLAG = "--xla_force_host_platform_device_count=8"


def _bench(n=16, m=32, sweeps=2):
    import jax

    from repro.core.models import heisenberg_j1j2_terms
    from repro.core.mpo import build_mpo, compress_mpo
    from repro.core.mps import neel_states, product_state_mps
    from repro.core.siteops import spin_half_space
    from repro.core.sweep import DMRGEngine
    from repro.dist import BlockShardPolicy, make_block_mesh
    from repro.dist.engine import ContractionEngine
    from repro.dist.plan import PlanCache

    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)

    def fresh_engine(**kw):
        mps = product_state_mps(sp, neel_states(sp, n))
        return DMRGEngine(mps, mpo, davidson_iters=2, **kw)

    def timed_sweeps(eng):
        eng.sweep(max_bond=m)  # grow bond + warm XLA/plan/jit caches
        t0 = time.perf_counter()
        for _ in range(sweeps):
            s = eng.sweep(max_bond=m)
        return (time.perf_counter() - t0) / sweeps, float(s.energy)

    rec = {"n_sites": n, "max_bond": m, "devices": jax.device_count()}

    t_seed, e_seed = timed_sweeps(fresh_engine(algo="list_unplanned"))
    rec["seed_unplanned_sweep_s"] = t_seed

    cache = PlanCache()
    eng = fresh_engine(engine=ContractionEngine(backend="list", cache=cache))
    t_plan, e_plan = timed_sweeps(eng)
    rec["planned_sweep_s"] = t_plan
    rec["plan_cache"] = cache.stats()
    rec["plan_speedup"] = t_seed / max(t_plan, 1e-12)

    t_jit, e_jit = timed_sweeps(fresh_engine(algo="list", jit_matvec=True))
    rec["planned_jit_sweep_s"] = t_jit
    rec["jit_speedup"] = t_seed / max(t_jit, 1e-12)

    t_auto, e_auto = timed_sweeps(fresh_engine(algo="auto"))
    rec["auto_sweep_s"] = t_auto

    policy = BlockShardPolicy(make_block_mesh())
    t_shard, e_shard = timed_sweeps(
        fresh_engine(algo="list", shard_policy=policy)
    )
    rec["sharded_sweep_s"] = t_shard
    rec["sharded_energy_diff"] = abs(e_shard - e_plan)
    rec["energy"] = e_plan
    assert abs(e_seed - e_plan) < 1e-10, (e_seed, e_plan)
    assert abs(e_seed - e_jit) < 1e-10, (e_seed, e_jit)
    assert abs(e_seed - e_auto) < 1e-8, (e_seed, e_auto)
    assert abs(e_seed - e_shard) < 1e-10, (e_seed, e_shard)
    return rec


def _child_main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    rec = _bench()
    print("BENCH_DIST_JSON " + json.dumps(rec))


def run():
    """run.py entry: execute in a subprocess (XLA flag must precede jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _XLA_FLAG).strip()
    env.setdefault("JAX_ENABLE_X64", "1")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child"],
        capture_output=True,
        text=True,
        env=env,
        timeout=3600,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"bench_dist child failed:\n{proc.stderr[-2000:]}")
    rec = None
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_DIST_JSON "):
            rec = json.loads(line[len("BENCH_DIST_JSON "):])
    assert rec is not None, proc.stdout
    out_path = os.path.join(os.path.dirname(__file__), "bench_dist.json")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    rows = [
        ("dist_seed_unplanned_sweep", rec["seed_unplanned_sweep_s"] * 1e6, ""),
        (
            "dist_planned_sweep",
            rec["planned_sweep_s"] * 1e6,
            f"speedup={rec['plan_speedup']:.2f}x;"
            f"cache_hits={rec['plan_cache']['hits']};"
            f"cache_misses={rec['plan_cache']['misses']}",
        ),
        (
            "dist_planned_jit_sweep",
            rec["planned_jit_sweep_s"] * 1e6,
            f"speedup={rec['jit_speedup']:.2f}x",
        ),
        ("dist_auto_sweep", rec["auto_sweep_s"] * 1e6, ""),
        (
            "dist_sharded_sweep",
            rec["sharded_sweep_s"] * 1e6,
            f"devices={rec['devices']};ediff={rec['sharded_energy_diff']:.1e}",
        ),
    ]
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child_main()
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
