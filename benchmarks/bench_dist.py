"""Distributed contraction engine: plan-cache, batching and jit benchmarks.

Weak-scaling style run on a 16-site m=32 Heisenberg chain comparing

- seed per-call contraction (``list_unplanned``) vs the plan-cached engine
  (``list``) vs the shape-bucketed batched backend and the compile-once
  (bucket-padded) jitted matvec, plus "auto" and an 8-fake-device
  mesh-sharded sweep (energy must match single-device).

Every configuration is swept to structural steady state (block structures
drift while the wavefunction converges, retracing jitted code and churning
plans) and reports **compile/warmup and steady-state separately**:
``*_first_sweep_s`` is the cold first sweep, ``*_sweep_s`` the mean of the
last ``TIMED`` sweeps, and jitted configs also record how many matvec
retraces happened inside the timed window (0 == compile-once achieved).

The run also splits each steady-state sweep into its three pipeline stages —
contraction+Davidson vs decomposition (``*_decomp_stage_s``, the summed
``svd_split`` wall time per sweep) vs environment updates
(``*_env_stage_s``, the summed left/right env-update wall time per sweep) —
and runs two dedicated stage microbenches: decomposition at m=64 (seed
per-sector loop vs planned batched engine, ``decomp_stage`` in the JSON)
and the environment stage at m=32 (``env_stage``): full left+right env
rebuild passes over the converged state through the eager three-call
``extend_left``/``extend_right`` path vs the fused jitted environment
engine (``dist/envcore.py``), asserting block-for-block agreement to
<1e-10 and zero retraces inside the timed window, and recording the stage
speedup.

Emits CSV rows (via benchmarks/run.py) and a JSON record at
``benchmarks/bench_dist.json`` so future PRs have a perf trajectory.  Must
run in its own process with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set *before* jax imports; ``main()`` below re-execs itself accordingly and
run.py invokes it as a subprocess.

``--quick`` (used by CI) runs only the acceptance-critical configurations
on the same workload — eager planned vs batched+jit vs list+jit — so its
``planned_sweep_s`` is directly comparable with the checked-in record;
``--check PATH`` exits nonzero if ``planned_sweep_s`` regressed more than
2x vs the record at PATH.

``--coldstart`` runs only the **cold-start leg** (also part of the full
run, ``cold_start`` in the JSON): two fresh subprocesses sharing one plan
store (``dist/persist.py``).  Process A sweeps against the empty store
(priming it) and finishes with the blocking export-compile pass — the
warmup contract from README "Cold start".  Process B activates the primed
store and must reach its first sweep with **zero plan builds** and within
a small multiple of steady state, vs the ~20x cost process A paid.  The
leg asserts builds==0 and primed/cold energy equality <1e-10 outright;
``--check`` additionally gates ``primed_first_s`` at 2x the checked-in
record.  The record is written to ``benchmarks/bench_coldstart.json``
(untracked; uploaded as a CI artifact by the ``coldstart`` job).

``--spmd`` runs only the **weak-scaling leg** (also part of the full run,
``weak_scaling`` in the JSON): one fresh subprocess per fake-device count
in {1, 2, 4, 8}, each sweeping the cold-start workload in true SPMD mode
(``run_dmrg(spmd=True)`` semantics: device-resident replicated block
storage + per-bucket shard_map collective GEMMs, docs/distributed.md)
against the single-program list reference.  Every count asserts energy
equality <1e-10 and zero compiled-SPMD-program growth inside the timed
window; the 4-device leg additionally times the gather-to-host baseline
(same batched algorithm, storage-mode policy) and asserts the SPMD sweep
is >=5x faster.  The record is written to ``benchmarks/bench_spmd.json``
(untracked; uploaded as a CI artifact by the ``spmd`` job); ``--check``
gates the 4-device ``spmd_steady_s`` at 2x the checked-in record.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_XLA_FLAG = "--xla_force_host_platform_device_count=8"

WARM = 4   # sweeps to reach structural steady state
TIMED = 2  # sweeps averaged for the steady-state number


def _bench_decomp_stage(fresh_engine, n, m64=64, warm_sweeps=3, reps=3):
    """Decomposition-stage microbench at m=64: seed loop vs planned engine.

    Converges a run at bond 64, rebuilds every pair tensor theta_j =
    T_j · T_{j+1}, and times the full set of splits through the seed
    per-sector loop (``svd_split_unplanned``) vs the planned batched engine
    (jit-warmed), blocking on every output block so jax's async dispatch
    cannot hide device work.  Asserts the two paths' absorbed products agree
    block-for-block to <1e-10 first (the gauge-invariant equality check).
    """
    import numpy as np

    from repro.dist.decomp import DecompositionEngine
    from repro.dist.plan import DecompPlanCache
    from repro.tensor.blocksparse import contract, svd_split_unplanned

    eng = fresh_engine(algo="list")
    for _ in range(warm_sweeps):
        eng.sweep(max_bond=m64)
    T = eng.mps.tensors
    thetas = [eng.contract_fn(T[j], T[j + 1], ((2,), (0,))) for j in range(n - 1)]

    deng = DecompositionEngine(cache=DecompPlanCache())

    def run_all(split):
        outs = [split(th, 2, m64)[:2] for th in thetas]
        for U, V in outs:
            for b in U.blocks.values():
                b.block_until_ready()
            for b in V.blocks.values():
                b.block_until_ready()
        return outs

    ref = run_all(svd_split_unplanned)  # warm numpy/lazy caches
    got = run_all(deng.svd_split)       # build plans + compile cores
    max_diff = 0.0
    for (Ur, Vr), (Up, Vp) in zip(ref, got):
        pr = np.asarray(contract(Ur, Vr, ((2,), (0,))).to_dense())
        pp = np.asarray(contract(Up, Vp, ((2,), (0,))).to_dense())
        max_diff = max(max_diff, float(np.max(np.abs(pr - pp))))
    assert max_diff < 1e-10, f"planned/seed split products diverge: {max_diff}"

    t0 = time.perf_counter()
    for _ in range(reps):
        run_all(svd_split_unplanned)
    seed_s = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        run_all(deng.svd_split)
    planned_s = (time.perf_counter() - t0) / reps
    return {
        "max_bond": m64,
        "n_thetas": len(thetas),
        "reps": reps,
        "seed_per_sector_s": seed_s,
        "planned_batched_s": planned_s,
        "speedup": seed_s / max(planned_s, 1e-12),
        "max_product_diff": max_diff,
        "decomp_stats": deng.stats(),
    }


def _bench_env_stage(fresh_engine, n, m=32, warm_sweeps=4, reps=5):
    """Environment-stage microbench at m=32: eager three-call vs fused jit.

    Converges a run at bond m, then times full environment rebuild passes —
    a left-to-right pass of ``extend_left`` plus a right-to-left pass of
    ``extend_right`` over every site — through (a) the seed-shaped eager
    path (three chained plan-cached engine calls per site) and (b) the
    fused jitted ``EnvironmentEngine`` on padded operands (one compiled
    call per site).  Asserts the two paths agree block-for-block to <1e-10
    and that the fused path triggers zero retraces inside the timed window,
    blocking on every env block so async dispatch cannot hide device work.
    """
    import numpy as np

    from repro.core.env import extend_left, extend_right, left_edge, right_edge
    from repro.dist.envcore import EnvironmentEngine
    from repro.dist.plan import EnvPlanCache

    eng = fresh_engine(algo="list", jit_env=False)
    for _ in range(warm_sweeps):
        eng.sweep(max_bond=m)
    T, W = eng.mps.tensors, eng.mpo
    ceng = eng.contract_fn  # the warm plan-cached eager engine
    fused = EnvironmentEngine(cache=EnvPlanCache())

    def block(envs):
        for t in envs:
            for b in t.blocks.values():
                b.block_until_ready()
        return envs

    def eager_pass():
        envs = []
        A = left_edge(T[0], W[0])
        for j in range(n - 1):
            A = extend_left(A, T[j], W[j], ceng)
            envs.append(A)
        B = right_edge(T[n - 1], W[n - 1])
        for j in range(n - 1, 0, -1):
            B = extend_right(B, T[j], W[j], ceng)
            envs.append(B)
        return block(envs)

    def fused_pass():
        envs = []
        A = left_edge(T[0], W[0])
        for j in range(n - 1):
            A = fused.update_left(A, T[j], W[j])
            envs.append(A)
        B = right_edge(T[n - 1], W[n - 1])
        for j in range(n - 1, 0, -1):
            B = fused.update_right(B, T[j], W[j])
            envs.append(B)
        return block(envs)

    ref = eager_pass()           # warm eager plans
    got = fused_pass()           # build env plans + compile fused cores
    max_diff = 0.0
    for tr, tf in zip(ref, got):
        assert set(tr.blocks) == set(tf.blocks)
        for k in tr.blocks:
            max_diff = max(max_diff, float(np.max(np.abs(
                np.asarray(tr.blocks[k]) - np.asarray(tf.blocks[k])
            ))))
    assert max_diff < 1e-10, f"fused/eager env updates diverge: {max_diff}"

    t0 = time.perf_counter()
    for _ in range(reps):
        eager_pass()
    eager_s = (time.perf_counter() - t0) / reps
    rt0 = fused.jit_retraces
    t0 = time.perf_counter()
    for _ in range(reps):
        fused_pass()
    fused_s = (time.perf_counter() - t0) / reps
    timed_retraces = fused.jit_retraces - rt0
    assert timed_retraces == 0, f"env core retraced in timed window: {timed_retraces}"
    return {
        "max_bond": m,
        "n_updates": 2 * (n - 1),
        "reps": reps,
        "eager_three_call_s": eager_s,
        "fused_jit_s": fused_s,
        "speedup": eager_s / max(fused_s, 1e-12),
        "timed_retraces": timed_retraces,
        "max_block_diff": max_diff,
        "env_stats": fused.stats(),
    }


def _bench(n=16, m=32, quick=False):
    import jax

    from repro.core.models import heisenberg_j1j2_terms
    from repro.core.mpo import build_mpo, compress_mpo
    from repro.core.mps import neel_states, product_state_mps
    from repro.core.siteops import spin_half_space
    from repro.core.sweep import DMRGEngine
    from repro.dist import BlockShardPolicy, make_block_mesh
    from repro.dist.engine import ContractionEngine
    from repro.dist.plan import PlanCache

    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)

    def fresh_engine(**kw):
        mps = product_state_mps(sp, neel_states(sp, n))
        return DMRGEngine(mps, mpo, davidson_iters=2, **kw)

    def timed_sweeps(eng, warm=WARM, timed=TIMED, bond=m):
        """(first_sweep_s, steady_sweep_s, energy, timed-window retraces,
        steady decomposition-stage seconds per sweep, steady env-stage
        seconds per sweep)."""
        t0 = time.perf_counter()
        eng.sweep(max_bond=bond)
        first = time.perf_counter() - t0
        for _ in range(warm - 1):
            eng.sweep(max_bond=bond)
        rt0 = getattr(eng.contract_fn, "jit_retraces", 0)
        t0 = time.perf_counter()
        svd_s = 0.0
        env_s = 0.0
        for _ in range(timed):
            s = eng.sweep(max_bond=bond)
            svd_s += s.svd_seconds
            env_s += s.env_seconds
        steady = (time.perf_counter() - t0) / timed
        rt1 = getattr(eng.contract_fn, "jit_retraces", 0)
        return first, steady, float(s.energy), rt1 - rt0, svd_s / timed, env_s / timed

    rec = {
        "n_sites": n,
        "max_bond": m,
        "devices": jax.device_count(),
        "warm_sweeps": WARM,
        "timed_sweeps": TIMED,
        "quick": quick,
    }

    # eager reference config: plan-cached engine, no jit anywhere — its env
    # stage is the seed-shaped three-call extend path, the A/B baseline for
    # the fused env numbers below
    cache = PlanCache()
    eng = fresh_engine(
        engine=ContractionEngine(backend="list", cache=cache), jit_env=False
    )
    t1_plan, t_plan, e_plan, _, d_plan, v_plan = timed_sweeps(eng)
    rec["planned_first_sweep_s"] = t1_plan
    rec["planned_sweep_s"] = t_plan
    # stage split: decomposition (svd_split wall clock) + environment
    # (env-update wall clock) vs everything else (contraction + Davidson)
    rec["planned_decomp_stage_s"] = d_plan
    rec["planned_env_stage_s"] = v_plan
    rec["planned_contract_stage_s"] = t_plan - d_plan - v_plan
    rec["planned_decomp_stats"] = eng.contract_fn.stats()["decomp"]
    rec["plan_cache"] = cache.stats()
    rec["energy"] = e_plan

    # tentpole config: shape-bucketed batched backend + compile-once
    # (bucket-padded) jitted matvec
    eng = fresh_engine(algo="batched", jit_matvec=True)
    t1_b, t_b, e_b, rt_b, d_b, v_b = timed_sweeps(eng)
    rec["batched_first_sweep_s"] = t1_b
    rec["batched_sweep_s"] = t_b
    rec["batched_decomp_stage_s"] = d_b
    rec["batched_env_stage_s"] = v_b
    rec["batched_contract_stage_s"] = t_b - d_b - v_b
    rec["batched_timed_retraces"] = rt_b
    rec["batched_total_retraces"] = eng.contract_fn.jit_retraces
    rec["batched_svd_retraces"] = eng.contract_fn.decomp.jit_retraces
    rec["batched_env_retraces"] = eng.contract_fn.env.jit_retraces
    rec["batched_env_stats"] = eng.contract_fn.stats()["env"]
    # robustness ledger: no faults are armed here, so the degradation
    # ladder must stay untouched — any nonzero counter means a backend
    # silently failed and fell back, which would skew every timing above
    st_b = eng.contract_fn.stats()
    rec["recovery_ledger"] = {
        "engine_retries": dict(st_b["retries"]),
        "engine_degradations": dict(st_b["degradations"]),
        "decomp_retries": st_b["decomp"]["retries"],
        "decomp_degradations": dict(st_b["decomp"]["degradations"]),
    }
    assert not any(st_b["retries"].values()), rec["recovery_ledger"]
    assert not any(st_b["degradations"].values()), rec["recovery_ledger"]
    assert st_b["decomp"]["retries"] == 0, rec["recovery_ledger"]
    assert not any(st_b["decomp"]["degradations"].values()), rec["recovery_ledger"]
    rec["batched_speedup"] = t_plan / max(t_b, 1e-12)
    rec["batched_energy_diff"] = abs(e_b - e_plan)
    # fused-vs-eager env stage inside full sweeps (the microbench below
    # isolates the same comparison on identical tensors)
    rec["env_stage_sweep_speedup"] = v_plan / max(v_b, 1e-12)

    eng = fresh_engine(algo="list", jit_matvec=True)
    t1_jit, t_jit, e_jit, rt_jit, _, _ = timed_sweeps(eng)
    rec["planned_jit_first_sweep_s"] = t1_jit
    rec["planned_jit_sweep_s"] = t_jit
    rec["planned_jit_timed_retraces"] = rt_jit
    rec["planned_jit_total_retraces"] = eng.contract_fn.jit_retraces
    rec["jit_speedup"] = t_plan / max(t_jit, 1e-12)

    assert abs(e_b - e_plan) < 1e-10, (e_b, e_plan)
    assert abs(e_jit - e_plan) < 1e-10, (e_jit, e_plan)

    rec["decomp_stage"] = _bench_decomp_stage(fresh_engine, n)
    rec["env_stage"] = _bench_env_stage(fresh_engine, n, m)

    if not quick:
        # the seed per-call algorithm is ~20x the planned engine, so it is
        # sampled at sweep 2 (warm=1, timed=1) rather than swept to steady
        # state — the ratio is labeled with its protocol
        t1_seed, t_seed, e_seed, _, _, _ = timed_sweeps(
            fresh_engine(algo="list_unplanned"), warm=1, timed=1
        )
        rec["seed_unplanned_sweep_s"] = t_seed
        rec["seed_unplanned_protocol"] = {"warm": 1, "timed": 1}
        # like-for-like ratio: planned engine sampled at the same sweep 2
        _, t_plan2, e_plan2, _, _, _ = timed_sweeps(
            fresh_engine(algo="list", jit_env=False), warm=1, timed=1
        )
        rec["planned_sweep2_s"] = t_plan2
        rec["plan_speedup_sweep2"] = t_seed / max(t_plan2, 1e-12)

        eng = fresh_engine(algo="batched")
        _, t_be, e_be, _, _, _ = timed_sweeps(eng)
        rec["batched_eager_sweep_s"] = t_be
        rec["batched_eager_stats"] = eng.contract_fn.stats()["backend_seconds"]

        _, t_auto, e_auto, _, _, _ = timed_sweeps(fresh_engine(algo="auto"))
        rec["auto_sweep_s"] = t_auto

        # sharded smoke on a reduced workload: on fake CPU devices the
        # storage-mode gathers dominate (~30x), so this records energy
        # equality plus a small timing sample, not a steady-state number
        ns, ms = 8, 16
        mps = product_state_mps(sp, neel_states(sp, ns))
        terms_s = heisenberg_j1j2_terms(ns // 2, 2, 1.0, 0.5, cylinder=False)
        mpo_s = compress_mpo(build_mpo(sp, terms_s, ns), cutoff=1e-13)
        single = DMRGEngine(mps, mpo_s, davidson_iters=2, algo="list")
        for _ in range(2):
            s_single = single.sweep(max_bond=ms)
        policy = BlockShardPolicy(make_block_mesh())
        sharded = DMRGEngine(
            product_state_mps(sp, neel_states(sp, ns)),
            mpo_s,
            davidson_iters=2,
            algo="list",
            shard_policy=policy,
        )
        sharded.sweep(max_bond=ms)
        t0 = time.perf_counter()
        s_shard = sharded.sweep(max_bond=ms)
        rec["sharded_smoke"] = {
            "n_sites": ns,
            "max_bond": ms,
            "sweep_s": time.perf_counter() - t0,
            "energy_diff": abs(float(s_shard.energy) - float(s_single.energy)),
        }
        assert rec["sharded_smoke"]["energy_diff"] < 1e-10, rec["sharded_smoke"]
        # seed and planned follow the same trajectory sweep-for-sweep
        assert abs(e_seed - e_plan2) < 1e-10, (e_seed, e_plan2)
        assert abs(e_be - e_plan) < 1e-10, (e_be, e_plan)
        assert abs(e_auto - e_plan) < 1e-8, (e_auto, e_plan)
    return rec


def _child_main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    rec = _bench(quick="--quick" in sys.argv)
    print("BENCH_DIST_JSON " + json.dumps(rec))


# ----------------------------------------------------------- cold-start leg

COLD_N = 8    # cold-start workload: small enough that the priming run and
COLD_M = 16   # its export-compile pass stay in CI budget, large enough that
              # plan building + compilation dominate a cold first sweep


def _bench_coldstart(store_dir, phase):
    """One cold-start subprocess: sweep the workload against ``store_dir``.

    ``phase="cold"``: the store is empty — this run primes it (plans +
    export artifacts saved as they are built) and finishes with the
    blocking ``prefetch_exports(compile=True)`` pass, which precompiles
    the deserialized-artifact wrappers into the persistent XLA cache (the
    second half of the warmup contract; without it a later process pays
    fresh XLA compiles for the wrapped modules).

    ``phase="primed"``: a fresh process against the primed store — the
    blocking compile prefetch runs first (worker-startup cost, reported
    separately), then the first sweep must find every plan and executable
    ready: zero plan builds, small first/steady ratio.
    """
    from repro.core.models import heisenberg_j1j2_terms
    from repro.core.mpo import build_mpo, compress_mpo
    from repro.core.mps import neel_states, product_state_mps
    from repro.core.siteops import spin_half_space
    from repro.core.sweep import DMRGEngine
    from repro.dist import cache_stats, persist

    n, m = COLD_N, COLD_M
    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    # activate BEFORE building the MPO: compression itself runs plan-cached
    # contractions, and those plans must round-trip too (run_dmrg orders the
    # activation the same way)
    store = persist.activate_store(store_dir, prefetch=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)

    prefetch_s = 0.0
    if phase == "primed":
        t0 = time.perf_counter()
        store.prefetch_exports(compile=True, block=True)
        prefetch_s = time.perf_counter() - t0

    mps = product_state_mps(sp, neel_states(sp, n))
    eng = DMRGEngine(mps, mpo, davidson_iters=2, algo="batched",
                     jit_matvec=True)
    t0 = time.perf_counter()
    s = eng.sweep(max_bond=m)
    first = time.perf_counter() - t0
    for _ in range(WARM - 1):
        eng.sweep(max_bond=m)
    t0 = time.perf_counter()
    for _ in range(TIMED):
        s = eng.sweep(max_bond=m)
    steady = (time.perf_counter() - t0) / TIMED

    if phase == "cold":
        # the warmup contract's second half: compile every artifact this
        # run just exported, so the primed process's wrappers hit the
        # persistent XLA cache instead of recompiling
        t0 = time.perf_counter()
        store.prefetch_exports(compile=True, block=True)
        prefetch_s = time.perf_counter() - t0

    st = cache_stats()
    return {
        "phase": phase,
        "first_s": first,
        "steady_s": steady,
        "prefetch_compile_s": prefetch_s,
        "energy": float(s.energy),
        "plan_builds": sum(
            st[k]["builds"]
            for k in ("plan_cache", "decomp_plan_cache", "env_plan_cache")
        ),
        "store": st["plan_store"],
    }


def _coldstart_child_main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    i = sys.argv.index("--child-coldstart")
    rec = _bench_coldstart(sys.argv[i + 1], sys.argv[i + 2])
    print("BENCH_COLDSTART_JSON " + json.dumps(rec))


def _coldstart_subprocess(store_dir, phase, env):
    cmd = [sys.executable, os.path.abspath(__file__), "--child-coldstart",
           store_dir, phase]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=3600
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"coldstart child ({phase}) failed:\n{proc.stderr[-2000:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_COLDSTART_JSON "):
            return json.loads(line[len("BENCH_COLDSTART_JSON "):])
    raise AssertionError(proc.stdout)


def _run_coldstart():
    """The cold-start leg: prime in process A, measure process B.

    Returns the ``cold_start`` record and asserts the leg's two hard
    invariants (independent of machine speed): the primed process built
    zero plans, and its energy trajectory is identical to the cold run's
    to <1e-10.
    """
    import tempfile

    env = dict(os.environ)
    env.setdefault("JAX_ENABLE_X64", "1")
    with tempfile.TemporaryDirectory(prefix="bench_coldstart_") as store_dir:
        cold = _coldstart_subprocess(store_dir, "cold", env)
        primed = _coldstart_subprocess(store_dir, "primed", env)
    steady = primed["steady_s"]
    rec = {
        "n_sites": COLD_N,
        "max_bond": COLD_M,
        "warm_sweeps": WARM,
        "timed_sweeps": TIMED,
        "cold_first_s": cold["first_s"],
        "cold_steady_s": cold["steady_s"],
        "warmup_compile_s": cold["prefetch_compile_s"],
        "primed_prefetch_s": primed["prefetch_compile_s"],
        "primed_first_s": primed["first_s"],
        "steady_s": steady,
        "cold_ratio": cold["first_s"] / max(steady, 1e-12),
        "primed_ratio": primed["first_s"] / max(steady, 1e-12),
        "primed_speedup": cold["first_s"] / max(primed["first_s"], 1e-12),
        "cold_plan_builds": cold["plan_builds"],
        "primed_plan_builds": primed["plan_builds"],
        "energy_diff": abs(cold["energy"] - primed["energy"]),
        "store_saves": cold["store"]["saves"],
        "store_export_saves": cold["store"]["export_saves"],
    }
    assert rec["primed_plan_builds"] == 0, rec
    assert rec["energy_diff"] < 1e-10, rec
    return rec


# ---------------------------------------------------------- weak-scaling leg

SPMD_N = 8    # weak-scaling workload: the cold-start J1-J2 ladder — small
SPMD_M = 16   # enough that four device counts fit in CI budget, block-rich
              # enough that every bucket shape class crosses the collectives
SPMD_DEVICES = (1, 2, 4, 8)
SPMD_GATE_DEVICES = 4    # device count carrying the gather-vs-spmd gate
SPMD_GATE_SPEEDUP = 5.0  # spmd must beat the gather-to-host path by this
SPMD_TIMED = 3           # timed sweeps per leg; steady state = min of these


def _bench_spmd(ndev):
    """One weak-scaling subprocess: list vs SPMD sweeps at ``ndev`` devices.

    Runs the SPMD (``mode="spmd"``, device-resident replicated storage +
    per-bucket shard_map collectives) sweep against the single-program list
    reference, reporting first/steady sweep seconds, the decomposition/env
    stage split, energy equality, and the SPMD collective ledger
    (``dist.spmd.stats()``) — with the hard compile-once check that the set
    of compiled SPMD programs stopped growing inside the timed window.

    At ``SPMD_GATE_DEVICES`` it also times the gather-to-host baseline the
    SPMD mode replaces: the *same* bucketed batched algorithm under a
    storage-mode policy, where every engine operation re-gathers the
    sharded blocks to replicated form on host before stacking buckets.
    That pair of numbers carries the acceptance gate (``SPMD_GATE_SPEEDUP``).

    Protocol: steady state is the MIN over ``SPMD_TIMED`` sweeps (robust
    to load spikes on shared CI runners, unlike the mean), and the SPMD
    leg warms two sweeps longer than the others — its first compile ramp
    (per-bucket shard_map programs inlined into the fused cores) has the
    longest tail.
    """
    import jax

    from repro.core.models import heisenberg_j1j2_terms
    from repro.core.mpo import build_mpo, compress_mpo
    from repro.core.mps import neel_states, product_state_mps
    from repro.core.siteops import spin_half_space
    from repro.core.sweep import DMRGEngine
    from repro.dist import BlockShardPolicy, make_block_mesh, spmd_stats

    assert jax.device_count() == ndev, (jax.device_count(), ndev)
    n, m = SPMD_N, SPMD_M
    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)

    def fresh(**kw):
        mps = product_state_mps(sp, neel_states(sp, n))
        return DMRGEngine(mps, mpo, davidson_iters=2, **kw)

    def timed(eng, warm=WARM):
        t0 = time.perf_counter()
        eng.sweep(max_bond=m)
        first = time.perf_counter() - t0
        for _ in range(warm - 1):
            eng.sweep(max_bond=m)
        sweeps = []
        svd_s = env_s = 0.0
        for _ in range(SPMD_TIMED):
            t0 = time.perf_counter()
            s = eng.sweep(max_bond=m)
            sweeps.append(time.perf_counter() - t0)
            svd_s += s.svd_seconds
            env_s += s.env_seconds
        steady = min(sweeps)
        return first, steady, float(s.energy), svd_s / SPMD_TIMED, env_s / SPMD_TIMED

    _, t_list, e_list, _, _ = timed(fresh(algo="list"))

    mesh = make_block_mesh()
    policy = BlockShardPolicy(mesh, mode="spmd")
    eng = fresh(algo="batched", jit_matvec=True, shard_policy=policy)
    t0 = time.perf_counter()
    eng.sweep(max_bond=m)
    first = time.perf_counter() - t0
    for _ in range(WARM + 1):
        eng.sweep(max_bond=m)
    progs0 = spmd_stats()["unique_programs"]
    sweeps = []
    svd_s = env_s = 0.0
    for _ in range(SPMD_TIMED):
        t0 = time.perf_counter()
        s = eng.sweep(max_bond=m)
        sweeps.append(time.perf_counter() - t0)
        svd_s += s.svd_seconds
        env_s += s.env_seconds
    steady = min(sweeps)
    prog_growth = spmd_stats()["unique_programs"] - progs0

    rec = {
        "devices": ndev,
        "mesh": [int(mesh.shape["row"]), int(mesh.shape["col"])],
        "list_steady_s": t_list,
        "spmd_first_s": first,
        "spmd_steady_s": steady,
        "spmd_decomp_stage_s": svd_s / SPMD_TIMED,
        "spmd_env_stage_s": env_s / SPMD_TIMED,
        "spmd_vs_list_ratio": steady / max(t_list, 1e-12),
        "energy_diff": abs(float(s.energy) - e_list),
        "timed_program_growth": prog_growth,
        "spmd_stats": spmd_stats(),
    }
    if ndev == SPMD_GATE_DEVICES:
        # the gather-to-host baseline: same algorithm, storage-mode policy
        gpol = BlockShardPolicy(make_block_mesh())  # auto -> storage on CPU
        assert gpol.storage_only
        _, t_gather, e_gather, _, _ = timed(
            fresh(algo="batched", shard_policy=gpol)
        )
        rec["gather_steady_s"] = t_gather
        rec["gather_energy_diff"] = abs(e_gather - e_list)
        rec["spmd_vs_gather_speedup"] = t_gather / max(steady, 1e-12)
    return rec


def _spmd_child_main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ndev = int(sys.argv[sys.argv.index("--child-spmd") + 1])
    rec = _bench_spmd(ndev)
    print("BENCH_SPMD_JSON " + json.dumps(rec))


def _spmd_subprocess(ndev):
    env = dict(os.environ)
    # replace any inherited device-count flag with this leg's count
    flags = [
        f for f in env.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    ]
    flags.append(f"--xla_force_host_platform_device_count={ndev}")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("JAX_ENABLE_X64", "1")
    cmd = [sys.executable, os.path.abspath(__file__), "--child-spmd", str(ndev)]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=3600
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"spmd child ({ndev} devices) failed:\n{proc.stderr[-2000:]}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_SPMD_JSON "):
            return json.loads(line[len("BENCH_SPMD_JSON "):])
    raise AssertionError(proc.stdout)


def _run_weak_scaling():
    """The weak-scaling leg: one subprocess per fake-device count.

    Each count gets its own process because the device count is fixed by
    ``XLA_FLAGS`` before jax imports.  Asserts, at every count: SPMD energy
    equals the list reference to <1e-10 and the compiled-program set
    stopped growing inside the timed window (compile-once).  At
    ``SPMD_GATE_DEVICES`` it additionally asserts the acceptance gate:
    SPMD steady sweep >= ``SPMD_GATE_SPEEDUP``x faster than the
    gather-to-host (storage-mode, same algorithm) baseline.
    """
    legs = {}
    for ndev in SPMD_DEVICES:
        leg = _spmd_subprocess(ndev)
        assert leg["energy_diff"] < 1e-10, leg
        assert leg["timed_program_growth"] == 0, leg
        legs[str(ndev)] = leg
    gate_leg = legs[str(SPMD_GATE_DEVICES)]
    assert gate_leg["gather_energy_diff"] < 1e-10, gate_leg
    speedup = gate_leg["spmd_vs_gather_speedup"]
    assert speedup >= SPMD_GATE_SPEEDUP, (
        f"spmd vs gather-to-host speedup {speedup:.2f}x at "
        f"{SPMD_GATE_DEVICES} devices is below the "
        f"{SPMD_GATE_SPEEDUP:.0f}x acceptance gate: {gate_leg}"
    )
    return {
        "n_sites": SPMD_N,
        "max_bond": SPMD_M,
        "warm_sweeps": WARM,
        "spmd_warm_sweeps": WARM + 2,
        "timed_sweeps": SPMD_TIMED,
        "steady_estimator": "min",
        "device_counts": list(SPMD_DEVICES),
        "legs": legs,
        "gate": {
            "devices": SPMD_GATE_DEVICES,
            "required_speedup": SPMD_GATE_SPEEDUP,
            "spmd_vs_gather_speedup": speedup,
        },
    }


def spmd_rows(ws):
    """CSV rows for a weak-scaling record (shared by full and --spmd)."""
    rows = [
        (
            f"dist_spmd_sweep_{ndev}dev",
            ws["legs"][str(ndev)]["spmd_steady_s"] * 1e6,
            f"vs_list={ws['legs'][str(ndev)]['spmd_vs_list_ratio']:.2f}x;"
            f"ediff={ws['legs'][str(ndev)]['energy_diff']:.1e};"
            f"programs={ws['legs'][str(ndev)]['spmd_stats']['unique_programs']}",
        )
        for ndev in ws["device_counts"]
    ]
    g = ws["gate"]
    rows.append((
        "dist_spmd_vs_gather",
        ws["legs"][str(g["devices"])]["gather_steady_s"] * 1e6,
        f"speedup={g['spmd_vs_gather_speedup']:.2f}x;"
        f"required={g['required_speedup']:.0f}x;devices={g['devices']}",
    ))
    return rows


def check_regression(rec, ref, factor=2.0):
    """Fail (return nonzero) if a gated timing regressed > factor vs ref.

    Gates ``planned_sweep_s`` when present, ``cold_start.primed_first_s``
    when both records carry a cold-start leg (the coldstart-only record from
    ``--coldstart`` has no ``planned_sweep_s``; a pre-cold-start reference
    has no ``cold_start``), and the gate-device-count SPMD steady sweep when
    both records carry a weak-scaling leg.
    """
    rc = 0
    if "planned_sweep_s" in rec:
        got, want = rec["planned_sweep_s"], ref["planned_sweep_s"]
        if got > factor * want:
            print(
                f"REGRESSION: planned_sweep_s {got:.3f}s > {factor:.1f}x "
                f"checked-in {want:.3f}s"
            )
            rc = 1
        else:
            print(f"planned_sweep_s {got:.3f}s vs checked-in {want:.3f}s: ok")
    if "cold_start" in rec and "cold_start" in ref:
        got = rec["cold_start"]["primed_first_s"]
        want = ref["cold_start"]["primed_first_s"]
        if got > factor * want:
            print(
                f"REGRESSION: cold_start.primed_first_s {got:.3f}s > "
                f"{factor:.1f}x checked-in {want:.3f}s"
            )
            rc = 1
        else:
            print(
                f"cold_start.primed_first_s {got:.3f}s vs checked-in "
                f"{want:.3f}s: ok"
            )
    if "weak_scaling" in rec and "weak_scaling" in ref:
        key = str(SPMD_GATE_DEVICES)
        got = rec["weak_scaling"]["legs"][key]["spmd_steady_s"]
        want = ref["weak_scaling"]["legs"][key]["spmd_steady_s"]
        if got > factor * want:
            print(
                f"REGRESSION: weak_scaling spmd_steady_s ({key} devices) "
                f"{got:.3f}s > {factor:.1f}x checked-in {want:.3f}s"
            )
            rc = 1
        else:
            print(
                f"weak_scaling spmd_steady_s ({key} devices) {got:.3f}s vs "
                f"checked-in {want:.3f}s: ok"
            )
    return rc


def run(quick=False, write_json=True):
    """run.py entry (CSV rows only); see ``_run`` for the JSON record."""
    return _run(quick=quick, write_json=write_json)[0]


def _run(quick=False, write_json=True):
    """Execute in a subprocess (XLA flag must precede jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _XLA_FLAG).strip()
    env.setdefault("JAX_ENABLE_X64", "1")
    cmd = [sys.executable, os.path.abspath(__file__), "--child"]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=3600
    )
    if proc.returncode != 0:
        raise RuntimeError(f"bench_dist child failed:\n{proc.stderr[-2000:]}")
    rec = None
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_DIST_JSON "):
            rec = json.loads(line[len("BENCH_DIST_JSON "):])
    assert rec is not None, proc.stdout
    if not quick:
        # the cold-start leg spawns its own pair of subprocesses (the whole
        # point is crossing a process boundary), so it runs from the parent;
        # the weak-scaling leg likewise needs one process per device count
        rec["cold_start"] = _run_coldstart()
        rec["weak_scaling"] = _run_weak_scaling()
    if write_json:
        out_path = os.path.join(os.path.dirname(__file__), "bench_dist.json")
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
    rows = [
        (
            "dist_planned_sweep",
            rec["planned_sweep_s"] * 1e6,
            f"first={rec['planned_first_sweep_s']:.2f}s;"
            f"cache_hits={rec['plan_cache']['hits']};"
            f"cache_misses={rec['plan_cache']['misses']}",
        ),
        (
            "dist_batched_jit_sweep",
            rec["batched_sweep_s"] * 1e6,
            f"speedup={rec['batched_speedup']:.2f}x;"
            f"timed_retraces={rec['batched_timed_retraces']};"
            f"decomp_stage_s={rec['batched_decomp_stage_s']:.3f}",
        ),
        (
            "dist_decomp_stage_m64",
            rec["decomp_stage"]["planned_batched_s"] * 1e6,
            f"speedup_vs_seed={rec['decomp_stage']['speedup']:.2f}x;"
            f"seed_s={rec['decomp_stage']['seed_per_sector_s']:.3f};"
            f"product_diff={rec['decomp_stage']['max_product_diff']:.1e}",
        ),
        (
            "dist_env_stage_m32",
            rec["env_stage"]["fused_jit_s"] * 1e6,
            f"speedup_vs_eager={rec['env_stage']['speedup']:.2f}x;"
            f"eager_s={rec['env_stage']['eager_three_call_s']:.3f};"
            f"timed_retraces={rec['env_stage']['timed_retraces']};"
            f"block_diff={rec['env_stage']['max_block_diff']:.1e}",
        ),
        (
            "dist_planned_jit_sweep",
            rec["planned_jit_sweep_s"] * 1e6,
            f"speedup={rec['jit_speedup']:.2f}x;"
            f"timed_retraces={rec['planned_jit_timed_retraces']}",
        ),
    ]
    if not quick:
        sm = rec["sharded_smoke"]
        rows = [
            (
                "dist_seed_unplanned_sweep2",
                rec["seed_unplanned_sweep_s"] * 1e6,
                f"vs_planned_sweep2={rec['plan_speedup_sweep2']:.2f}x",
            ),
        ] + rows + [
            ("dist_batched_eager_sweep", rec["batched_eager_sweep_s"] * 1e6, ""),
            ("dist_auto_sweep", rec["auto_sweep_s"] * 1e6, ""),
            (
                "dist_sharded_smoke_sweep",
                sm["sweep_s"] * 1e6,
                f"devices={rec['devices']};n={sm['n_sites']};"
                f"ediff={sm['energy_diff']:.1e}",
            ),
        ] + coldstart_rows(rec["cold_start"]) + spmd_rows(rec["weak_scaling"])
    return rows, rec


def coldstart_rows(cs):
    """CSV rows for a cold-start record (shared by full and --coldstart)."""
    return [
        (
            "dist_coldstart_primed_first_sweep",
            cs["primed_first_s"] * 1e6,
            f"ratio_vs_steady={cs['primed_ratio']:.2f}x;"
            f"speedup_vs_cold={cs['primed_speedup']:.2f}x;"
            f"plan_builds={cs['primed_plan_builds']}",
        ),
        (
            "dist_coldstart_cold_first_sweep",
            cs["cold_first_s"] * 1e6,
            f"ratio_vs_steady={cs['cold_ratio']:.2f}x;"
            f"warmup_compile_s={cs['warmup_compile_s']:.1f};"
            f"ediff={cs['energy_diff']:.1e}",
        ),
    ]


if __name__ == "__main__":
    if "--child-coldstart" in sys.argv:
        _coldstart_child_main()
        sys.exit(0)
    if "--child-spmd" in sys.argv:
        _spmd_child_main()
        sys.exit(0)
    if "--child" in sys.argv:
        _child_main()
    else:
        quick = "--quick" in sys.argv
        ref = None
        if "--check" in sys.argv:
            # load the reference BEFORE running: a full (non-quick) run
            # rewrites bench_dist.json, and the gate must not compare the
            # fresh record against itself
            try:
                ref_path = sys.argv[sys.argv.index("--check") + 1]
            except IndexError:
                sys.exit("--check requires a path to a reference JSON")
            with open(ref_path) as f:
                ref = json.load(f)
        if "--spmd" in sys.argv:
            # weak-scaling-only mode (the CI spmd job): skip the in-process
            # bench and run just the per-device-count SPMD leg
            rec = {"quick": True, "weak_scaling": _run_weak_scaling()}
            for name, us, derived in spmd_rows(rec["weak_scaling"]):
                print(f"{name},{us:.1f},{derived}")
            out = os.path.join(os.path.dirname(__file__), "bench_spmd.json")
            with open(out, "w") as f:
                json.dump(rec, f, indent=2, sort_keys=True)
            print(f"wrote {out}")
            sys.exit(check_regression(rec, ref) if ref is not None else 0)
        if "--coldstart" in sys.argv:
            # coldstart-only mode (the CI coldstart job): skip the in-process
            # bench entirely and run just the two-subprocess leg
            rec = {"quick": True, "cold_start": _run_coldstart()}
            for name, us, derived in coldstart_rows(rec["cold_start"]):
                print(f"{name},{us:.1f},{derived}")
            out = os.path.join(
                os.path.dirname(__file__), "bench_coldstart.json"
            )
            with open(out, "w") as f:
                json.dump(rec, f, indent=2, sort_keys=True)
            print(f"wrote {out}")
            sys.exit(check_regression(rec, ref) if ref is not None else 0)
        rows, rec = _run(quick=quick, write_json=not quick)
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        if quick:
            out = os.path.join(os.path.dirname(__file__), "bench_dist_quick.json")
            with open(out, "w") as f:
                json.dump(rec, f, indent=2, sort_keys=True)
            print(f"wrote {out}")
        if ref is not None:
            sys.exit(check_regression(rec, ref))
