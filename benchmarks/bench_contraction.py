"""Paper Fig. 5 / Figs. 10, 13 analogue: performance rate of the three
block-sparse contraction algorithms on the DMRG Davidson matvec.

Measures wall time per matvec and derives GFLOP/s (flops counted exactly
from the block structure, as the paper counts via CTF's instrumentation).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.env import get_contractor, left_edge, matvec_two_site, right_edge
from repro.core.models import heisenberg_j1j2_terms
from repro.core.mpo import build_mpo, compress_mpo
from repro.core.mps import neel_states, product_state_mps
from repro.core.siteops import spin_half_space
from repro.core.sweep import DMRGEngine
from repro.tensor.blocksparse import contract


def _matvec_flops(A, Wj, Wj1, B, x) -> float:
    """Exact flop count of the list-algorithm matvec (block pair sums)."""
    total = 0.0

    def count(a, b, axes):
        nonlocal total
        ax_a, ax_b = axes
        sig = {}
        for kb in b.blocks:
            sig.setdefault(tuple(kb[i] for i in ax_b), []).append(kb)
        for ka, ablk in a.blocks.items():
            s = tuple(ka[i] for i in ax_a)
            for kb in sig.get(s, ()):  # matching blocks
                m = np.prod([d for i, d in enumerate(ablk.shape) if i not in ax_a])
                kk = np.prod([ablk.shape[i] for i in ax_a])
                n = np.prod([d for i, d in enumerate(b.blocks[kb].shape)
                             if i not in ax_b])
                total += 2.0 * m * kk * n

    # mirror matvec_two_site's contraction sequence
    count(A, x, ((2,), (0,)))
    t = contract(A, x, ((2,), (0,)))
    count(t, Wj, ((1, 2), (0, 2)))
    t = contract(t, Wj, ((1, 2), (0, 2)))
    count(t, Wj1, ((4, 1), (0, 2)))
    t = contract(t, Wj1, ((4, 1), (0, 2)))
    count(t, B, ((4, 1), (1, 2)))
    return total


def setup(m: int):
    """Grow a spins MPS to bond dim m and return mid-chain matvec operands."""
    sp = spin_half_space()
    n = 10
    terms = heisenberg_j1j2_terms(5, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)
    mps = product_state_mps(sp, neel_states(sp, n))
    eng = DMRGEngine(mps, mpo, algo="list", davidson_iters=2)
    for mm in (8, 16, 32, 64, 128):
        if mm > m:
            break
        eng.sweep(max_bond=min(mm, m))
    # after a full sweep the center is at site 0 and left_envs are stale;
    # rebuild a consistent environment pair for the mid-chain site
    from repro.core.env import extend_left

    eng2 = DMRGEngine(eng.mps, mpo, algo="list", davidson_iters=2)
    j = n // 2 - 1
    for i in range(j):
        eng2.left_envs[i + 1] = extend_left(
            eng2.left_envs[i], eng2.mps.tensors[i], mpo[i])
    A, B = eng2.left_envs[j], eng2.right_envs[j + 1]
    theta = contract(eng2.mps.tensors[j], eng2.mps.tensors[j + 1], ((2,), (0,)))
    return A, mpo[j], mpo[j + 1], B, theta


# The paper-figure rows must time the seed *per-call* algorithms (plan
# re-derivation included, as the paper's implementations do); get_contractor's
# plain names now return the plan-cached engine, which after warmup is a 100%
# cache hit and measures something else.  The "list" row keeps the engine for
# an unplanned-vs-planned comparison in the same table.
def run(ms=(16, 32, 64),
        algos=("list_unplanned", "dense_unplanned", "csr_unplanned", "list"),
        reps=3):
    rows = []
    for m in ms:
        A, Wj, Wj1, B, theta = setup(m)
        flops = _matvec_flops(A, Wj, Wj1, B, theta)
        for algo in algos:
            cf = get_contractor(algo)
            y = matvec_two_site(A, Wj, Wj1, B, theta, cf)  # warmup/trace
            jax.block_until_ready(list(y.blocks.values()))
            t0 = time.perf_counter()
            for _ in range(reps):
                y = matvec_two_site(A, Wj, Wj1, B, theta, cf)
                jax.block_until_ready(list(y.blocks.values()))
            dt = (time.perf_counter() - t0) / reps
            rows.append((f"contraction_m{m}_{algo}", dt * 1e6,
                         f"{flops / dt / 1e9:.3f}GFLOP/s"))
    return rows
