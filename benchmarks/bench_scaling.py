"""Paper Table II analogue: complexity / BSP cost model per algorithm,
evaluated on the empirical block model b_l = floor((m/q) r^l) with the
paper's fitted constants (q=4, r=0.6 spins; q=10, r=0.65 electrons), plus
the weak-scaling law the paper demonstrates (double nodes per doubled m:
work/node x8, memory/node x4 — their Fig. 8 commentary).
"""
from __future__ import annotations

import time

import numpy as np


def block_model(m: int, q: float, r: float):
    dims = []
    b = m / q
    while b >= 1.0 and sum(dims) < m:
        dims.append(int(b))
        b *= r
    return dims


def table2_costs(m: int, k: int, d: int, q: float, r: float, p: int):
    """Flops and BSP comm per Davidson matvec, per the paper's Table II."""
    dims = block_model(m, q, r)
    nb = len(dims)
    mq = m / q
    md = mq * mq * k * d * d            # Davidson working-set elements M_D
    return dict(
        n_blocks=nb,
        flops_list=mq**3 * k * d**2,
        flops_dense=float(m) ** 3 * k * d**2,
        supersteps_list=nb,
        supersteps_sparse=1,
        comm_list=md / p ** (2 / 3),
        comm_sparse=md / p ** 0.5,
    )


def run():
    rows = []
    for system, (q, r, k, d) in {
        "spins": (4, 0.6, 30, 2), "electrons": (10, 0.65, 26, 4)
    }.items():
        for m in (4096, 8192, 16384, 32768):
            t0 = time.perf_counter()
            c = table2_costs(m, k, d, q, r, p=256)
            dt = time.perf_counter() - t0
            rows.append((
                f"table2_{system}_m{m}", dt * 1e6,
                f"Nb={c['n_blocks']};Flist={c['flops_list']:.3e};"
                f"Fdense={c['flops_dense']:.3e};"
                f"comm_list={c['comm_list']:.3e};comm_sparse={c['comm_sparse']:.3e}",
            ))
        # weak scaling law: nodes n -> m = m0 * n (paper Fig. 8: near-ideal
        # efficiency when doubling nodes with m)
        for nodes in (1, 2, 4, 8):
            m = 4096 * nodes
            c = table2_costs(m, k, d, q, r, p=16 * nodes)
            work_per_node = c["flops_list"] / nodes
            rows.append((
                f"weakscale_{system}_n{nodes}", 0.0,
                f"m={m};work/node={work_per_node:.3e};"
                f"rel={work_per_node / (table2_costs(4096, k, d, q, r, 16)['flops_list']):.2f}",
            ))
    return rows
