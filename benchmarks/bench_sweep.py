"""Paper Fig. 6 analogue: per-site sweep time uniformity.

The paper times only the middle column of sites, arguing interior sites are
uniform; we verify: interior per-site optimization times vary by < ~2x while
edge sites are much cheaper.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.models import heisenberg_j1j2_terms
from repro.core.mpo import build_mpo, compress_mpo
from repro.core.mps import neel_states, product_state_mps
from repro.core.siteops import spin_half_space
from repro.core.sweep import DMRGEngine


def run(m=32, n=12):
    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)
    mps = product_state_mps(sp, neel_states(sp, n))
    eng = DMRGEngine(mps, mpo, algo="list", davidson_iters=2)
    eng.sweep(max_bond=m)      # grow + warm caches
    stats = eng.sweep(max_bond=m)
    lr = stats.site_seconds[: n - 1]  # left-to-right half sweep
    interior = lr[2 : n - 3]
    rows = [(f"sweep_site{j}", t * 1e6, "") for j, t in enumerate(lr)]
    rows.append((
        "sweep_uniformity", float(np.mean(interior)) * 1e6,
        f"interior_max/min={max(interior) / max(min(interior), 1e-9):.2f};"
        f"edge/interior={lr[0] / max(np.mean(interior), 1e-9):.2f}",
    ))
    return rows
