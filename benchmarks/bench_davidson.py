"""Paper Alg. 1 / Fig. 1d: Davidson iteration cost scaling with bond dim.

Times the full Davidson routine (subspace 2, as in production sweeps) on the
mid-chain pair, confirming the O(m^3 k d) matvec dominates.
"""
from __future__ import annotations

import time

import jax

from repro.core.davidson import davidson
from repro.core.env import matvec_two_site
from repro.tensor.blocksparse import contract
from .bench_contraction import setup


def run(ms=(16, 32, 64)):
    rows = []
    for m in ms:
        A, Wj, Wj1, B, theta = setup(m)

        def mv(x):
            return matvec_two_site(A, Wj, Wj1, B, x)

        lam, x, _ = davidson(mv, theta, n_iter=2)  # warmup
        t0 = time.perf_counter()
        lam, x, _ = davidson(mv, theta, n_iter=2)
        jax.block_until_ready(list(x.blocks.values()))
        dt = time.perf_counter() - t0
        rows.append((f"davidson_m{m}", dt * 1e6, f"lambda={lam:.6f}"))
    return rows
