# One function per paper table. Prints ``name,us_per_call,derived`` CSV and
# (for the dist suite) writes benchmarks/bench_dist.json as a perf record.
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (bench_blocks, bench_contraction, bench_davidson,
                            bench_dist, bench_lm, bench_scaling, bench_serve,
                            bench_sweep)

    suites = [
        ("Fig5/10/13: contraction algorithms", bench_contraction.run),
        ("Fig2: block structure", bench_blocks.run),
        ("TableII: cost model + weak scaling", bench_scaling.run),
        ("Alg1: Davidson", bench_davidson.run),
        ("Fig6: sweep uniformity", bench_sweep.run),
        # subprocess: needs --xla_force_host_platform_device_count before jax
        ("Dist: plan cache + mesh sharding", bench_dist.run),
        ("Serve: batched multi-problem throughput", bench_serve.run),
        ("LM cells (beyond paper)", bench_lm.run),
    ]
    print("name,us_per_call,derived")
    for title, fn in suites:
        print(f"# {title}", flush=True)
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness running
            print(f"{title}_FAILED,0,{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
