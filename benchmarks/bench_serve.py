"""Serving throughput benchmark: problems/sec at batch sizes {1, 8, 64}.

The serving subsystem's claim is that a parameter sweep batches through ONE
compiled pipeline with a leading problem axis, so throughput (problems/sec)
grows far faster than linearly in compute cost as the batch widens — the
per-block GEMMs at smoke scale are tiny, and vmapping B problems into one
dispatch amortizes the Python/dispatch overhead that dominates them.

Workload: heisenberg chain, n=8, bond schedule (8, 16), 2 sweeps per bond,
6 Davidson iterations — the smoke config of the acceptance gate.  For each
batch size B the J coupling sweeps linspace(0.8, 1.2, B); one untimed solve
warms every trace, then ``REPS`` timed solves through the shared
``StackedOps`` must run with ZERO retraces.  Batch-8 energies are checked
against 8 independent single-problem runs to 1e-10 before any number is
reported, and the record asserts batch-8 problems/sec >= 2x batch-1.

Emits CSV rows via benchmarks/run.py and writes a JSON record to
``benchmarks/bench_serve.json`` (tracked, the perf trajectory).  ``--quick``
(CI) runs batches {1, 8} with fewer reps and writes the untracked
``benchmarks/bench_serve_quick.json``; ``--check PATH`` exits nonzero if the
batch-8 vs batch-1 speedup fell below half the record at PATH (the speedup is
a within-machine ratio, so the gate holds across differently-sized runners
where absolute problems/sec would not).
"""
from __future__ import annotations

import json
import os
import sys
import time

os.environ.setdefault("JAX_ENABLE_X64", "1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

N_SITES = 8
MAX_BOND = 16
DAVIDSON_ITERS = 6
SWEEP_H = 0.3


def _specs_for(B):
    import numpy as np

    from repro.serve import ProblemSpec

    return [
        ProblemSpec.make(
            "heisenberg",
            N_SITES,
            J=float(j),
            h=SWEEP_H,
            max_bond=MAX_BOND,
            davidson_iters=DAVIDSON_ITERS,
        )
        for j in np.linspace(0.8, 1.2, B)
    ]


def _solve(space, mpos, spec, ops):
    from repro.serve import run_dmrg_multi

    return run_dmrg_multi(
        space,
        N_SITES,
        mpos,
        bond_schedule=spec.bond_schedule,
        sweeps_per_bond=spec.sweeps_per_bond,
        cutoff=spec.cutoff,
        davidson_iters=spec.davidson_iters,
        ops=ops,
    )


def _bench(quick=False):
    from repro.core import run_dmrg
    from repro.serve import StackedOps
    from repro.serve.problems import build_problem

    batches = (1, 8) if quick else (1, 8, 64)
    reps = 2 if quick else 3
    ops = StackedOps()
    per_batch = {}
    checked = None
    for B in batches:
        specs = _specs_for(B)
        built = [build_problem(s) for s in specs]
        space = built[0][0]
        mpos = [m for _, m in built]
        _solve(space, mpos, specs[0], ops)  # warm: trace this batch size
        floor = ops.retraces
        t0 = time.perf_counter()
        for _ in range(reps):
            res = _solve(space, mpos, specs[0], ops)
        dt = time.perf_counter() - t0
        retraces = ops.retraces - floor
        assert retraces == 0, (
            f"batch {B}: {retraces} retraces in the timed window"
        )
        per_batch[B] = {
            "batch": B,
            "reps": reps,
            "seconds_per_batch": dt / reps,
            "problems_per_sec": B * reps / dt,
            "retraces_timed": retraces,
        }
        if B == 8:  # correctness gate before any throughput claim
            worst = 0.0
            for b, spec in enumerate(specs):
                ref = run_dmrg(
                    space,
                    None,
                    N_SITES,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    davidson_iters=spec.davidson_iters,
                    cutoff=spec.cutoff,
                    mpo=mpos[b],
                    algo="batched",
                    jit_matvec=True,
                )
                worst = max(worst, abs(float(res.energies[b]) - ref.energy))
            assert worst < 1e-10, (
                f"batched energies diverge from singles: {worst}"
            )
            checked = worst
    speedup = (
        per_batch[8]["problems_per_sec"] / per_batch[1]["problems_per_sec"]
    )
    assert speedup >= 2.0, (
        f"batch-8 throughput only {speedup:.2f}x batch-1 (need >= 2x)"
    )
    return {
        "workload": {
            "model": "heisenberg",
            "n_sites": N_SITES,
            "max_bond": MAX_BOND,
            "sweeps_per_bond": 2,
            "davidson_iters": DAVIDSON_ITERS,
            "j_range": [0.8, 1.2],
            "h": SWEEP_H,
        },
        "quick": quick,
        "per_batch": {str(k): v for k, v in per_batch.items()},
        "speedup_8v1": speedup,
        "max_energy_diff_vs_single": checked,
    }


def _record(quick=False):
    return _bench(quick=quick)


def _rows(rec):
    rows = []
    for key in sorted(rec["per_batch"], key=int):
        r = rec["per_batch"][key]
        rows.append(
            (
                f"serve_batch{key}_problems_per_sec",
                1e6 / max(r["problems_per_sec"], 1e-12),
                f"{r['problems_per_sec']:.3f}/s",
            )
        )
    rows.append(
        ("serve_speedup_8v1", 0.0, f"{rec['speedup_8v1']:.2f}x")
    )
    rows.append(
        (
            "serve_batch8_max_energy_diff",
            0.0,
            f"{rec['max_energy_diff_vs_single']:.2e}",
        )
    )
    return rows


def run(quick=False, write_json=True):
    """run.py entry point: yields (name, us_per_call, derived) CSV rows."""
    rec = _record(quick=quick)
    if write_json and not quick:
        out = os.path.join(os.path.dirname(__file__), "bench_serve.json")
        with open(out, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
    return _rows(rec)


def main():
    quick = "--quick" in sys.argv
    ref = None
    if "--check" in sys.argv:
        # load the reference BEFORE running: a full run rewrites
        # bench_serve.json and the gate must not compare a record to itself
        ref_path = sys.argv[sys.argv.index("--check") + 1]
        with open(ref_path) as f:
            ref = json.load(f)
    rec = _record(quick=quick)
    out_name = "bench_serve_quick.json" if quick else "bench_serve.json"
    out = os.path.join(os.path.dirname(__file__), out_name)
    with open(out, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    for name, us, derived in _rows(rec):
        print(f"{name},{us:.1f},{derived}", flush=True)
    print(f"record written to {out}")
    if ref is not None:
        got = rec["speedup_8v1"]
        want = ref["speedup_8v1"]
        print(f"check: batch-8 speedup {got:.2f}x vs record {want:.2f}x")
        if got < want / 2.0:
            print("CHECK FAILED: batch-8 speedup regressed > 2x vs record",
                  file=sys.stderr)
            return 1
        print("CHECK OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
