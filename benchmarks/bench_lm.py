"""LM-side microbenchmarks (beyond-paper cells): smoke-config train-step and
decode-step throughput per architecture, plus kernel-vs-reference timings in
interpret mode (structural, not perf-representative on CPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import make_train_step
from repro.train.optim import OptConfig, init_opt_state


def run(archs=("llama3_8b", "rwkv6_3b", "qwen2_moe_a27b", "recurrentgemma_2b",
               "whisper_tiny"), b=2, s=64, reps=3):
    rows = []
    for arch in archs:
        cfg = get_config(arch).smoke()
        params, _ = models.init(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        batch = {
            "tokens": jnp.ones((b, s), jnp.int32),
            "labels": jnp.ones((b, s), jnp.int32),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((b, cfg.n_patches, cfg.d_model),
                                              jnp.float32)
        if cfg.family == "audio":
            batch["enc_embeds"] = jnp.zeros((b, cfg.enc_seq_len, cfg.d_model),
                                            jnp.float32)
        step = jax.jit(make_train_step(cfg, OptConfig()))
        params2, opt2, m = step(params, opt, batch)  # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(reps):
            params2, opt2, m = step(params2, opt2, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"train_step_{arch}", dt * 1e6,
                     f"{b * s / dt:.0f}tok/s"))

        cache = models.init_cache(cfg, b, 32)
        dstep = jax.jit(lambda p, c, t, pos: models.decode_step(cfg, p, c, t, pos))
        if cfg.family == "audio":
            from repro.models.whisper import whisper_prime_cache
            cache = whisper_prime_cache(
                cfg, params, cache,
                jnp.zeros((b, cfg.enc_seq_len, cfg.d_model), jnp.float32))
        tok = jnp.ones((b,), jnp.int32)
        logits, cache = dstep(params, cache, tok, jnp.int32(0))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for i in range(reps):
            logits, cache = dstep(params, cache, tok, jnp.int32(i + 1))
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"decode_step_{arch}", dt * 1e6, f"{b / dt:.0f}tok/s"))
    return rows
