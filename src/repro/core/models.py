"""The paper's two benchmark Hamiltonians (Sec. V).

*spins*     : 2D J1-J2 Heisenberg model at J2=0.5 on an Lx x Ly cylinder
              (periodic around y, open along x), d=2.
*electrons* : triangular-lattice Hubbard model, t=1, U=8.5, d=4, cylinder.

Site numbering: n = x*Ly + y (column-major along the cylinder axis), matching
the paper's column-of-10-sites sweep timing (their Fig. 6).
"""
from __future__ import annotations

from typing import List, Tuple

from .opterm import OpTerm, fermi_hop, term
from .siteops import LocalSpace, electron_space, spin_half_space


def _site(x: int, y: int, ly: int) -> int:
    return x * ly + (y % ly)


def heisenberg_j1j2_terms(
    lx: int, ly: int, j1: float = 1.0, j2: float = 0.5, cylinder: bool = True
) -> List[OpTerm]:
    """S_i . S_j = 0.5 (S+_i S-_j + S-_i S+_j) + Sz_i Sz_j over J1/J2 bonds."""
    bonds: List[Tuple[int, int, float]] = []

    def add_bond(i: int, j: int, coef: float):
        if i == j:
            return
        a, b = min(i, j), max(i, j)
        bonds.append((a, b, coef))

    for x in range(lx):
        for y in range(ly):
            i = _site(x, y, ly)
            # J1: +x neighbor, +y neighbor (wrap if cylinder)
            if x + 1 < lx:
                add_bond(i, _site(x + 1, y, ly), j1)
            if y + 1 < ly or (cylinder and ly > 2):
                add_bond(i, _site(x, y + 1, ly), j1)
            # J2: diagonal neighbors
            if x + 1 < lx:
                if y + 1 < ly or (cylinder and ly > 2):
                    add_bond(i, _site(x + 1, y + 1, ly), j2)
                if y - 1 >= 0 or (cylinder and ly > 2):
                    add_bond(i, _site(x + 1, y - 1, ly), j2)

    # dedupe (cylinder wrap can double-count on small Ly)
    seen = set()
    terms: List[OpTerm] = []
    for a, b, c in bonds:
        if (a, b, c) in seen:
            continue
        seen.add((a, b, c))
        terms.append(term(0.5 * c, ("S+", a), ("S-", b)))
        terms.append(term(0.5 * c, ("S-", a), ("S+", b)))
        terms.append(term(c, ("Sz", a), ("Sz", b)))
    return terms


def triangular_hubbard_terms(
    lx: int, ly: int, t: float = 1.0, u: float = 8.5, cylinder: bool = True
) -> List[OpTerm]:
    """-t sum_<ij>,sigma (c†_i c_j + h.c.) + U sum_i n_up n_dn on the
    triangular lattice: neighbors +x, +y, and +x-y (cylinder around y)."""
    sp = electron_space()
    bonds: List[Tuple[int, int]] = []

    def add_bond(i: int, j: int):
        if i != j:
            bonds.append((min(i, j), max(i, j)))

    for x in range(lx):
        for y in range(ly):
            i = _site(x, y, ly)
            if x + 1 < lx:
                add_bond(i, _site(x + 1, y, ly))
            if y + 1 < ly or (cylinder and ly > 2):
                add_bond(i, _site(x, y + 1, ly))
            if x + 1 < lx and (y - 1 >= 0 or (cylinder and ly > 2)):
                add_bond(i, _site(x + 1, y - 1, ly))

    terms: List[OpTerm] = []
    seen = set()
    for a, b in bonds:
        if (a, b) in seen:
            continue
        seen.add((a, b))
        for spin in ("up", "dn"):
            terms += fermi_hop(
                -t, f"adag_{spin}", f"a_{spin}", a, b, f"adagF_{spin}", f"Fa_{spin}"
            )
    for n in range(lx * ly):
        terms.append(OpTerm(u, (("nupdn", n),)))
    return terms


def heisenberg_chain_terms(n: int, j: float = 1.0, h: float = 0.0) -> List[OpTerm]:
    """Nearest-neighbor Heisenberg chain J sum_i S_i . S_i+1 + h sum_i Sz_i.

    The (J, h) parameterization is the serving subsystem's sweep axis: every
    (J, h) with h != 0 shares one MPO block structure (and h == 0 another),
    so parameter sweeps batch through a single compiled core.
    """
    terms: List[OpTerm] = []
    for i in range(n - 1):
        terms.append(term(0.5 * j, ("S+", i), ("S-", i + 1)))
        terms.append(term(0.5 * j, ("S-", i), ("S+", i + 1)))
        terms.append(term(j, ("Sz", i), ("Sz", i + 1)))
    if h != 0.0:
        for i in range(n):
            terms.append(term(h, ("Sz", i)))
    return terms


def heisenberg_chain_system(n: int, j: float = 1.0, h: float = 0.0):
    return spin_half_space(), heisenberg_chain_terms(n, j, h)


def spin_system(lx: int, ly: int, j2: float = 0.5):
    return spin_half_space(), heisenberg_j1j2_terms(lx, ly, 1.0, j2)


def electron_system(lx: int, ly: int, t: float = 1.0, u: float = 8.5):
    return electron_space(), triangular_hubbard_terms(lx, ly, t, u)
