"""Operator-string terms (the AutoMPO-style front end).

A Hamiltonian is a list of ``OpTerm``: coefficient times a product of named
single-site operators at distinct sites, plus an optional *connector* operator
threaded through every intermediate site (identity for bosonic strings, the
JW parity F for fermionic hopping).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class OpTerm:
    coef: complex
    ops: Tuple[Tuple[str, int], ...]   # ((opname, site), ...) sorted by site
    connector: str = "Id"

    def __post_init__(self):
        sites = [s for _, s in self.ops]
        assert sites == sorted(sites) and len(set(sites)) == len(sites), (
            f"operator sites must be strictly increasing: {sites}"
        )

    @property
    def sites(self) -> Tuple[int, ...]:
        return tuple(s for _, s in self.ops)


def term(coef, *ops, connector: str = "Id") -> OpTerm:
    """term(J, ("Sz", i), ("Sz", j)) — sites auto-sorted (bosonic only)."""
    ops = tuple(sorted(ops, key=lambda t: t[1]))
    return OpTerm(coef, ops, connector)


def fermi_hop(coef, adag_op: str, a_op: str, i: int, j: int,
              adagF_op: str, Fa_op: str) -> List[OpTerm]:
    """coef * (c†_i c_j + c†_j c_i) for i != j with JW strings.

    For i<j:  c†_i c_j = (a†F)_i [F...] (a)_j
              c†_j c_i = (Fa)_i  [F...] (a†)_j
    """
    if i > j:
        i, j = j, i
    return [
        OpTerm(coef, ((adagF_op, i), (a_op, j)), connector="F"),
        OpTerm(coef, ((Fa_op, i), (adag_op, j)), connector="F"),
    ]
