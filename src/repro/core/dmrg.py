"""Top-level DMRG driver: bond-dimension schedule + sweeps (paper Sec. II-C).

"In doing DMRG, we gradually increase bond dimension of the MPS, sweeping
over all sites multiple times for each successive bond dimension choice."
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp

from ..dist import persist
from ..dist.shard import BlockShardPolicy, make_block_mesh
from .checkpoint import (
    CheckpointManager,
    pack_run_state,
    tensor_restore,
    unpack_envs,
)
from .mpo import build_mpo, compress_mpo
from .mps import MPS, neel_states, product_state_mps
from .siteops import LocalSpace
from .sweep import DMRGEngine, SweepStats


@dataclasses.dataclass
class DMRGResult:
    energy: float
    mps: MPS
    sweep_stats: List[SweepStats]

    @property
    def energies(self) -> List[float]:
        return [s.energy for s in self.sweep_stats]


def run_dmrg(
    space: LocalSpace,
    terms,
    n_sites: int,
    bond_schedule: Sequence[int] = (8, 16, 32),
    sweeps_per_bond: int = 2,
    cutoff: float = 1e-12,
    algo: str = "list",
    davidson_iters: int = 3,
    mpo_cutoff: float = 1e-13,
    initial_states: Optional[Sequence[int]] = None,
    dtype=jnp.float64,
    verbose: bool = False,
    jit_matvec: bool = False,
    pad_matvec: Optional[bool] = None,
    shard_policy: Optional[BlockShardPolicy] = None,
    spmd: bool = False,
    svd_method: Optional[str] = None,
    jit_env: Optional[bool] = None,
    mpo=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 2,
    plan_store=None,
) -> DMRGResult:
    """Ground-state DMRG over a bond-dimension schedule.

    With ``checkpoint_dir`` set, the full sweep state (MPS, both env lists,
    schedule position, partial in-sweep accumulators, Davidson seed) is
    pickled atomically every ``checkpoint_every`` site updates plus at every
    sweep boundary, and a rerun with the same arguments resumes from the
    newest checkpoint — mid-sweep if that is where it died — with energies
    identical to the uninterrupted run (core/checkpoint.py).

    ``plan_store`` (a ``repro.dist.PlanStore`` or a path) activates the
    persistent plan + executable store for the duration of the run
    (``dist/persist.py``, DESIGN.md Sec. 3.9): plans, exported cores and
    compiled executables are loaded from — and written back to — the store,
    so a primed store takes the first sweep from ~20x steady-state cost to
    ~2x.  Physics is unchanged: primed and cold runs produce energies equal
    to <1e-10 (tests/test_persist.py).  A store already activated
    process-wide (``repro.dist.activate_store``) is used without passing it
    here; this argument scopes one to a single run.

    ``spmd=True`` turns on true SPMD execution (DESIGN.md 3.10,
    docs/distributed.md): MPS/MPO/environment tensors are pinned
    device-resident on the 2-D ("row", "col") mesh and every bucketed GEMM
    of the matvec and env stages runs as a shard_map collective program
    (``dist/spmd.py``).  It implies ``jit_matvec=True`` (the compile-once
    padded pipeline is what makes the collectives pay) and requires an
    engine-backed ``algo``.  Pass ``shard_policy`` to control the mesh (its
    mode must be "spmd"); omitted, a policy over all devices is built.
    Energies equal the single-device run to <1e-10 at any device count
    (tests/test_spmd.py).
    """
    if spmd:
        if shard_policy is None:
            shard_policy = BlockShardPolicy(make_block_mesh(), mode="spmd")
        elif shard_policy.mode != "spmd":
            raise ValueError(
                f"spmd=True needs a shard_policy with mode='spmd', got "
                f"mode={shard_policy.mode!r} (storage-mode policies keep the "
                f"gather-to-host path; pass spmd=False for that)"
            )
        jit_matvec = True
    with contextlib.ExitStack() as stack:
        if plan_store is not None:
            stack.enter_context(persist.using_store(plan_store))
        return _run_dmrg_body(
            space, terms, n_sites, bond_schedule, sweeps_per_bond, cutoff,
            algo, davidson_iters, mpo_cutoff, initial_states, dtype, verbose,
            jit_matvec, pad_matvec, shard_policy, svd_method, jit_env, mpo,
            checkpoint_dir, checkpoint_every, checkpoint_keep,
        )


def _run_dmrg_body(
    space, terms, n_sites, bond_schedule, sweeps_per_bond, cutoff, algo,
    davidson_iters, mpo_cutoff, initial_states, dtype, verbose, jit_matvec,
    pad_matvec, shard_policy, svd_method, jit_env, mpo, checkpoint_dir,
    checkpoint_every, checkpoint_keep,
) -> DMRGResult:
    # A pre-built MPO bypasses build/compress so callers comparing against a
    # batched multi-problem run (repro/serve) optimize the EXACT same
    # operator, not a re-compressed cousin with reordered degenerate blocks.
    if mpo is None:
        mpo = build_mpo(space, terms, n_sites, dtype=dtype)
        if mpo_cutoff is not None:
            mpo = compress_mpo(mpo, cutoff=mpo_cutoff)
    states = list(initial_states) if initial_states is not None else neel_states(space, n_sites)
    mps = product_state_mps(space, states, dtype=dtype)

    ckpt = (
        CheckpointManager(
            checkpoint_dir, every=checkpoint_every, keep=checkpoint_keep
        )
        if checkpoint_dir is not None
        else None
    )
    state = ckpt.load_latest() if ckpt is not None else None
    restored_envs = None
    stats: List[SweepStats] = []
    step = 0
    start_bi = start_si = 0
    sweep_resume = None
    if state is not None:
        mps.tensors = [tensor_restore(s) for s in state["mps"]]
        restored_envs = unpack_envs(state)
        stats = [SweepStats(**d) for d in state["stats"]]
        step = int(state["step"])
        start_bi, start_si = int(state["bond_idx"]), int(state["sweep_idx"])
        sweep_resume = state["sweep_resume"]

    engine = DMRGEngine(
        mps,
        mpo,
        algo=algo,
        davidson_iters=davidson_iters,
        jit_matvec=jit_matvec,
        pad_matvec=pad_matvec,
        shard_policy=shard_policy,
        svd_method=svd_method,
        jit_env=jit_env,
        restored_envs=restored_envs,
    )
    if state is not None:
        engine.seed = int(state["seed"])

    def _snapshot(bi: int, si: int, resume_state):
        return pack_run_state(
            step=step,
            bond_idx=bi,
            sweep_idx=si,
            sweep_resume=resume_state,
            mps_tensors=engine.mps.tensors,
            left_envs=engine.left_envs,
            right_envs=engine.right_envs,
            stats=stats,
            seed=engine.seed,
        )

    for bi, m in enumerate(bond_schedule):
        if bi < start_bi:
            continue
        for si in range(sweeps_per_bond):
            if bi == start_bi and si < start_si:
                continue
            resume = (
                sweep_resume if (bi, si) == (start_bi, start_si) else None
            )
            on_site = None
            if ckpt is not None:

                def on_site(rs, _bi=bi, _si=si):
                    nonlocal step
                    step += 1
                    if rs is not None:  # sweep boundary saved below instead
                        ckpt.maybe_save(_snapshot(_bi, _si, rs))

            s = engine.sweep(
                max_bond=m, cutoff=cutoff, resume=resume, on_site=on_site
            )
            stats.append(s)
            if ckpt is not None:
                # boundary checkpoint points at the NEXT schedule slot, so a
                # crash between sweeps resumes cleanly at the next sweep
                nbi, nsi = (
                    (bi, si + 1) if si + 1 < sweeps_per_bond else (bi + 1, 0)
                )
                ckpt.save(_snapshot(nbi, nsi, None))
            if verbose:
                print(
                    f"m={m:6d} E={s.energy:+.10f} maxbond={s.max_bond} "
                    f"trunc={s.trunc_err:.2e} t={s.seconds:.2f}s"
                )
    return DMRGResult(energy=stats[-1].energy, mps=engine.mps, sweep_stats=stats)
