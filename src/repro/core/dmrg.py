"""Top-level DMRG driver: bond-dimension schedule + sweeps (paper Sec. II-C).

"In doing DMRG, we gradually increase bond dimension of the MPS, sweeping
over all sites multiple times for each successive bond dimension choice."
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp

from ..dist.shard import BlockShardPolicy
from .mpo import build_mpo, compress_mpo
from .mps import MPS, neel_states, product_state_mps
from .siteops import LocalSpace
from .sweep import DMRGEngine, SweepStats


@dataclasses.dataclass
class DMRGResult:
    energy: float
    mps: MPS
    sweep_stats: List[SweepStats]

    @property
    def energies(self) -> List[float]:
        return [s.energy for s in self.sweep_stats]


def run_dmrg(
    space: LocalSpace,
    terms,
    n_sites: int,
    bond_schedule: Sequence[int] = (8, 16, 32),
    sweeps_per_bond: int = 2,
    cutoff: float = 1e-12,
    algo: str = "list",
    davidson_iters: int = 3,
    mpo_cutoff: float = 1e-13,
    initial_states: Optional[Sequence[int]] = None,
    dtype=jnp.float64,
    verbose: bool = False,
    jit_matvec: bool = False,
    pad_matvec: Optional[bool] = None,
    shard_policy: Optional[BlockShardPolicy] = None,
    svd_method: Optional[str] = None,
    jit_env: Optional[bool] = None,
    mpo=None,
) -> DMRGResult:
    # A pre-built MPO bypasses build/compress so callers comparing against a
    # batched multi-problem run (repro/serve) optimize the EXACT same
    # operator, not a re-compressed cousin with reordered degenerate blocks.
    if mpo is None:
        mpo = build_mpo(space, terms, n_sites, dtype=dtype)
        if mpo_cutoff is not None:
            mpo = compress_mpo(mpo, cutoff=mpo_cutoff)
    states = list(initial_states) if initial_states is not None else neel_states(space, n_sites)
    mps = product_state_mps(space, states, dtype=dtype)
    engine = DMRGEngine(
        mps,
        mpo,
        algo=algo,
        davidson_iters=davidson_iters,
        jit_matvec=jit_matvec,
        pad_matvec=pad_matvec,
        shard_policy=shard_policy,
        svd_method=svd_method,
        jit_env=jit_env,
    )

    stats: List[SweepStats] = []
    for m in bond_schedule:
        for _ in range(sweeps_per_bond):
            s = engine.sweep(max_bond=m, cutoff=cutoff)
            stats.append(s)
            if verbose:
                print(
                    f"m={m:6d} E={s.energy:+.10f} maxbond={s.max_bond} "
                    f"trunc={s.trunc_err:.2e} t={s.seconds:.2f}s"
                )
    return DMRGResult(energy=stats[-1].energy, mps=engine.mps, sweep_stats=stats)
