"""DMRG core: the paper's primary contribution, on the block-sparse substrate."""
from .checkpoint import CheckpointManager, pack_run_state, tensor_restore, tensor_state
from .davidson import DavidsonInfo, davidson
from .dmrg import DMRGResult, run_dmrg
from .ed import build_dense_hamiltonian, ground_energy
from .env import expectation, get_contractor, matvec_two_site
from .models import electron_system, spin_system
from .mpo import build_mpo, compress_mpo, mpo_bond_dims
from .mps import MPS, neel_states, product_state_mps, total_charge
from .siteops import electron_space, spin_half_space
from .sweep import DMRGEngine

__all__ = [
    "CheckpointManager", "pack_run_state", "tensor_restore", "tensor_state",
    "DavidsonInfo",
    "davidson", "DMRGResult", "run_dmrg", "build_dense_hamiltonian",
    "ground_energy", "expectation", "get_contractor", "matvec_two_site",
    "electron_system", "spin_system", "build_mpo", "compress_mpo",
    "mpo_bond_dims", "MPS", "neel_states", "product_state_mps",
    "total_charge", "electron_space", "spin_half_space", "DMRGEngine",
]
