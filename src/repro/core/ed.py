"""Dense exact-diagonalization oracle for small systems (test reference)."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .opterm import OpTerm
from .siteops import LocalSpace


def build_dense_hamiltonian(space: LocalSpace, terms: Sequence[OpTerm], n: int) -> np.ndarray:
    d = space.d
    H = np.zeros((d**n, d**n))
    for t in terms:
        mats = [np.eye(d) for _ in range(n)]
        sites = t.sites
        for (opname, s) in t.ops:
            mats[s] = mats[s] @ np.asarray(space.ops[opname])
        for s in range(sites[0] + 1, sites[-1]):
            if s not in sites:
                mats[s] = mats[s] @ np.asarray(space.ops[t.connector])
        acc = np.ones((1, 1))
        for s in range(n):  # site 0 = most significant kron factor
            acc = np.kron(acc, mats[s])
        H += float(np.real(t.coef)) * acc
    return H


def state_charges_vector(space: LocalSpace, n: int) -> np.ndarray:
    """Total charge of each product basis state, shape [d^n, nq]."""
    d = space.d
    nq = len(space.state_charges[0])
    qs = np.array(space.state_charges)  # [d, nq]
    out = np.zeros((d**n, nq), dtype=np.int64)
    for s in range(n):
        reps = d ** (n - s - 1)
        tiles = d**s
        col = np.repeat(np.tile(np.arange(d), tiles), reps)
        out += qs[col]
    return out


def ground_energy(space: LocalSpace, terms: Sequence[OpTerm], n: int, charge=None) -> float:
    """Smallest eigenvalue of H, optionally restricted to a charge sector."""
    H = build_dense_hamiltonian(space, terms, n)
    if charge is not None:
        mask = np.all(state_charges_vector(space, n) == np.array(charge), axis=1)
        H = H[np.ix_(mask, mask)]
        assert H.shape[0] > 0, f"empty charge sector {charge}"
    return float(np.linalg.eigvalsh(H)[0])
