"""Two-site DMRG sweeps (paper Sec. II-C, Fig. 1c-e).

Maintains left/right environments incrementally, optimizes each neighboring
pair with Davidson, splits with a blockwise truncated SVD absorbing the
singular values along the sweep direction, and supports all contraction
backends ("list", "dense", "csr", "batched", "auto") through the
plan-cached ``dist.ContractionEngine``.  Optional extras when the backend
is an engine (the default): a jitted planned matvec (``jit_matvec=True``)
with bucket-padded operands so it compiles once per quantized structure
(``pad_matvec``, defaulting to the jit flag), and a ``BlockShardPolicy``
that keeps MPS/MPO/environment blocks mesh-sharded, mirroring the paper's
distribute-every-block-over-all-processors layout.  A policy in "spmd"
mode (``run_dmrg(spmd=True)``) instead pins every stored tensor
device-resident on the mesh — uploaded once in ``__init__``/``_init_envs``
— and the engine executes all bucketed GEMMs as shard_map collective
programs (``dist/spmd.py``, DESIGN.md 3.10); "storage" mode keeps the
gather-before-compute fallback.

The decomposition stage goes through the engine too (``svd_method``): the
planned batched SVD (``dist/decomp.py``) by default, the seed per-sector
loop with ``svd_method="unplanned"``, or the randomized path
("randomized"/"auto") — so ``_optimize_pair`` stays in device-land from the
matvec through the split, with one host sync per split for truncation.
``SweepStats.svd_seconds`` reports the stage's wall-clock per sweep.

The environment stage is the fourth and final pipeline stage under the
engine (``jit_env``, defaulting on for engines): each left/right env update
runs as ONE fused jitted call (``dist/envcore.py``) on power-of-two-padded
operands instead of three chained eager contractions, and ``_init_envs``
rebuilds the right environments as one planned right-to-left pass.
``jit_env=False`` (or a bare contractor) falls back to the seed
``extend_left`` / ``extend_right``; ``SweepStats.env_seconds`` carries the
stage's wall-clock per sweep.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from ..dist import faults
from ..dist.batch import pad_block_sparse, unpad_block_sparse
from ..dist.engine import ContractionEngine
from ..dist.faults import FaultInjected, NumericalHealthError
from ..dist.shard import BlockShardPolicy
from ..tensor.blocksparse import (
    BlockSparseTensor,
    contract,
    flip_flow,
    svd_split_unplanned,
)
from .davidson import davidson
from .env import (
    extend_left,
    extend_right,
    get_contractor,
    left_edge,
    matvec_two_site,
    right_edge,
)
from .mps import MPS


@dataclasses.dataclass
class SweepStats:
    energy: float
    max_bond: int
    trunc_err: float
    seconds: float
    site_seconds: List[float]
    site_energies: List[float]
    # wall-clock of the decomposition stage (all svd_split calls) this sweep,
    # in seconds — the per-stage split bench_dist.py reports.  For the
    # planned path this includes the singular-value device sync, so it
    # reflects real SVD compute; the remainder of ``seconds`` is
    # contraction + Davidson + environment work.
    svd_seconds: float = 0.0
    # wall-clock of the environment stage (all left/right env updates) this
    # sweep, in seconds — fused jitted updates when ``jit_env`` is on, the
    # seed three-contraction path otherwise.  Host-side dispatch time (jax
    # is async), like the contraction engine's ``backend_seconds``.
    env_seconds: float = 0.0
    # Davidson health ledger for the sweep (core/davidson.py DavidsonInfo):
    # solves run, solves whose residual actually converged below tol (budget-
    # limited production solves stop early, so converged < solves is normal),
    # total inner iterations, Gram-Schmidt breakdown restarts, and subspace
    # exhaustions.  Restarts/exhaustions > 0 on a healthy small problem is
    # expected near convergence; they become interesting when they spike.
    davidson_solves: int = 0
    davidson_converged: int = 0
    davidson_iterations: int = 0
    davidson_restarts: int = 0
    davidson_exhausted: int = 0
    # pair optimizations that failed the fast path (NumericalHealthError /
    # injected fault) and were recovered on the seed ladder rung.  Zero on a
    # healthy run — the clean bench leg asserts it.
    pair_retries: int = 0


class DMRGEngine:
    """Alternating two-site optimization with incremental environments."""

    def __init__(
        self,
        mps: MPS,
        mpo: List[BlockSparseTensor],
        algo: str = "list",
        davidson_iters: int = 2,
        seed: int = 0,
        jit_matvec: bool = False,
        pad_matvec: Optional[bool] = None,
        shard_policy: Optional[BlockShardPolicy] = None,
        engine: Optional[Callable] = None,
        svd_method: Optional[str] = None,
        jit_env: Optional[bool] = None,
        restored_envs=None,
    ):
        assert mps.n_sites == len(mpo)
        self.mps = mps
        self.mpo = mpo
        self.algo = algo
        self.contract_fn = engine if engine is not None else get_contractor(algo)
        self.jit_matvec = jit_matvec
        # bucket-pad the Davidson operands so the jitted matvec sees a small
        # set of block structures (compile-once); defaults to on iff jitting
        self.pad_matvec = jit_matvec if pad_matvec is None else pad_matvec
        # the MPO is immutable for the run — pad each site tensor once,
        # not on every pair optimization
        self._mpo_padded: List[Optional[BlockSparseTensor]] = [None] * len(mpo)
        if svd_method not in (None, "unplanned", "svd", "randomized", "auto"):
            raise ValueError(f"unknown svd_method: {svd_method!r}")
        if isinstance(self.contract_fn, ContractionEngine):
            # decomposition stage: engines route svd_split through their
            # planned DecompositionEngine ("svd" exact, "randomized", "auto"
            # cost model); "unplanned" forces the seed per-sector loop.  The
            # svd_method and shard_policy parameters are the single source of
            # truth: set them on the engine, or reset configuration left over
            # from a previous DMRGEngine that reused this engine instance
            self.svd_planned = svd_method != "unplanned"
            self.contract_fn.decomp.method = (
                svd_method if svd_method in ("svd", "randomized", "auto")
                else "svd"
            )
            self.contract_fn.policy = shard_policy
            # environment stage: fused plan-cached jitted updates
            # (dist/envcore.py) by default for engines; jit_env=False keeps
            # the seed extend_left/extend_right three-call path
            self.jit_env = True if jit_env is None else bool(jit_env)
        else:
            # bare contractors (the *_unplanned algos, or a plain callable
            # passed via engine=) have no gather step (sharded blocks would
            # deadlock eager CPU collectives), no jit pipeline and no planned
            # decomposition; fail loudly instead of hanging / silently
            # ignoring the flag
            backend = (
                f"algo={algo!r}" if engine is None
                else f"engine={type(engine).__name__}"
            )
            if shard_policy is not None:
                raise ValueError(
                    f"shard_policy requires a ContractionEngine backend, "
                    f"not {backend}"
                )
            if jit_matvec:
                raise ValueError(
                    f"jit_matvec requires a ContractionEngine backend, "
                    f"not {backend}"
                )
            if svd_method not in (None, "unplanned"):
                raise ValueError(
                    f"svd_method={svd_method!r} requires a ContractionEngine "
                    f"backend, not {backend}; bare contractors use the seed "
                    f"svd_split_unplanned"
                )
            if jit_env:
                raise ValueError(
                    f"jit_env requires a ContractionEngine backend, "
                    f"not {backend}; bare contractors use the seed "
                    f"extend_left/extend_right"
                )
            self.svd_planned = False
            self.jit_env = False
        if shard_policy is not None:
            self.mps.tensors = shard_policy.place_mps(self.mps.tensors)
            self.mpo = shard_policy.place_mps(self.mpo)
        self.shard_policy = shard_policy
        self.davidson_iters = davidson_iters
        self.seed = seed
        self.n = mps.n_sites
        if restored_envs is not None:
            # checkpoint resume (core/checkpoint.py): the serialized env
            # lists are exact copies of the live ones at save time, so
            # restoring them — rather than recomputing via _init_envs —
            # keeps a mid-sweep resume bit-identical to the uninterrupted
            # run (the right envs mid-LR-sweep are partially stale, a state
            # a fresh rebuild could not reproduce)
            self.left_envs, self.right_envs = restored_envs
            assert len(self.left_envs) == self.n + 1
            assert len(self.right_envs) == self.n + 1
        else:
            self._init_envs()

    def _init_envs(self):
        n = self.n
        T, W = self.mps.tensors, self.mpo
        self.left_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        self.right_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        # edges placed too: under an spmd-mode policy this is the one-time
        # device-resident upload — every stored env (and the MPS/MPO placed
        # in __init__) lives replicated on the mesh from here on and is
        # never re-materialized on host between sites
        self.left_envs[0] = self._place(left_edge(T[0], W[0]))
        self.right_envs[n - 1] = self._place(right_edge(T[n - 1], W[n - 1]))
        # build right envs down to site 1 (first pair needs right_envs[1]) —
        # one planned right-to-left pass: fused jitted updates when jit_env
        for j in range(n - 2, 0, -1):
            self.right_envs[j] = self._place(self._extend_right_env(j))

    def _extend_left_env(self, j: int) -> BlockSparseTensor:
        """A_{j+1} from A_j: absorb site j into the left environment.

        Planned fused jitted update (``engine.env_update_left``) when
        ``jit_env`` is on; the seed three-contraction ``extend_left``
        otherwise (and always for bare contractors).
        """
        A, T, W = self.left_envs[j], self.mps.tensors[j], self.mpo[j]
        if self.jit_env:
            try:
                return self.contract_fn.env_update_left(
                    A, T, W, mpo_padded=self._padded_mpo(j)
                )
            except Exception:
                # degradation ladder (DESIGN.md 3.8): fused core failed —
                # recover on the seed three-contraction path, which matches
                # it to <1e-10 block-for-block, and keep sweeping
                self.contract_fn.note_retry("env")
                self.contract_fn.note_degradation("env_seed")
        return extend_left(A, T, W, self.contract_fn)

    def _extend_right_env(self, j: int) -> BlockSparseTensor:
        """B_j from B_{j+1}: absorb site j+1 into the right environment."""
        B, T, W = self.right_envs[j + 1], self.mps.tensors[j + 1], self.mpo[j + 1]
        if self.jit_env:
            try:
                return self.contract_fn.env_update_right(
                    B, T, W, mpo_padded=self._padded_mpo(j + 1)
                )
            except Exception:
                self.contract_fn.note_retry("env")
                self.contract_fn.note_degradation("env_seed")
        return extend_right(B, T, W, self.contract_fn)

    def _padded_mpo(self, j: int) -> BlockSparseTensor:
        if self._mpo_padded[j] is None:
            self._mpo_padded[j] = pad_block_sparse(self.mpo[j])
        return self._mpo_padded[j]

    def _place(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Mesh-shard a stored tensor (env / site) when a policy is attached."""
        return t if self.shard_policy is None else self.shard_policy.place(t)

    def _optimize_pair(self, j: int, max_bond: int, cutoff: float, absorb: str):
        """Optimize pair (j, j+1), recovering failures on the seed rung.

        The fast path is the full engine pipeline (planned matvec, batched
        SVD).  A ``NumericalHealthError`` (a health guard at a host sync saw
        non-finite values — e.g. a NaN-poisoned GEMM surfacing at the
        Davidson Rayleigh-Ritz read) or an injected fault aborts the pair
        BEFORE any MPS tensor is written, so the retry starts from exactly
        the pre-pair state and re-runs on the seed code path: eager seed
        ``contract`` matvec, seed per-sector SVD, no engine involvement —
        immune to any engine-layer fault still armed.  Seed-equality
        guarantees (<1e-10) make the recovered energy match a clean run.
        """
        try:
            return self._optimize_pair_fast(j, max_bond, cutoff, absorb)
        except (NumericalHealthError, FaultInjected):
            if isinstance(self.contract_fn, ContractionEngine):
                self.contract_fn.note_retry("pair")
                self.contract_fn.note_degradation("pair_seed")
            return self._optimize_pair_seed(j, max_bond, cutoff, absorb)

    def _optimize_pair_seed(
        self, j: int, max_bond: int, cutoff: float, absorb: str
    ):
        """Bottom degradation rung: the pair on seed-only code paths."""
        T, W = self.mps.tensors, self.mpo
        A, B = self.left_envs[j], self.right_envs[j + 1]
        Tj, Tj1, Wj, Wj1 = T[j], T[j + 1], W[j], W[j + 1]
        if self.shard_policy is not None:
            # the seed contract is eager per-block; gather sharded operands
            # first (same rule as the engine's storage-mode gather)
            rep = self.shard_policy.replicated
            A, B = rep(A), rep(B)
            Tj, Tj1, Wj, Wj1 = rep(Tj), rep(Tj1), rep(Wj), rep(Wj1)
        theta = contract(Tj, Tj1, ((2,), (0,)))

        def mv(x):
            return matvec_two_site(A, Wj, Wj1, B, x, contract)

        lam, theta, info = davidson(
            mv, theta, n_iter=self.davidson_iters, seed=self.seed + j
        )
        t_svd = time.perf_counter()
        U, V, _, err = svd_split_unplanned(
            theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb
        )
        svd_dt = time.perf_counter() - t_svd
        T[j] = self._place(flip_flow(U, 2))
        T[j + 1] = self._place(flip_flow(V, 0))
        return lam, err, svd_dt, info

    def _optimize_pair_fast(
        self, j: int, max_bond: int, cutoff: float, absorb: str
    ):
        T, W = self.mps.tensors, self.mpo
        A, B = self.left_envs[j], self.right_envs[j + 1]
        theta = self.contract_fn(T[j], T[j + 1], ((2,), (0,)))

        pad = (
            self.pad_matvec and isinstance(self.contract_fn, ContractionEngine)
        )
        if pad:
            # round every sector dim up to a power of two: zero-padding is
            # exact (padded operator entries are zero) and quantizes the
            # traced structure, so the jitted matvec compiles once per
            # bucketed structure instead of once per site per sweep
            orig_indices = theta.indices
            A, B = pad_block_sparse(A), pad_block_sparse(B)
            Wjp, Wj1p = self._padded_mpo(j), self._padded_mpo(j + 1)
            theta = pad_block_sparse(theta)
        else:
            Wjp, Wj1p = W[j], W[j + 1]

        if isinstance(self.contract_fn, ContractionEngine):
            mv = self.contract_fn.matvec_fn(
                A, Wjp, Wj1p, B, jit=self.jit_matvec
            )
        else:
            def mv(x):
                return matvec_two_site(A, Wjp, Wj1p, B, x, self.contract_fn)

        lam, theta, dinfo = davidson(
            mv, theta, n_iter=self.davidson_iters, seed=self.seed + j
        )
        if pad:
            theta = unpad_block_sparse(theta, orig_indices)
        # decomposition stage: planned engines stay in device-land — one
        # batched SVD core call plus a single singular-value sync for the
        # global truncation — while the seed path loops sectors on host
        t_svd = time.perf_counter()
        if self.svd_planned:
            U, V, _, err = self.contract_fn.svd_split(
                theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb
            )
        else:
            U, V, _, err = svd_split_unplanned(
                theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb
            )
        svd_dt = time.perf_counter() - t_svd
        T[j] = self._place(flip_flow(U, 2))
        T[j + 1] = self._place(flip_flow(V, 0))
        return lam, err, svd_dt, dinfo

    def sweep(
        self,
        max_bond: int,
        cutoff: float = 1e-12,
        resume: Optional[Dict] = None,
        on_site: Optional[Callable[[Optional[Dict]], None]] = None,
    ) -> SweepStats:
        """One full left-to-right + right-to-left sweep; returns stats.

        ``resume`` restarts mid-sweep from a state dict previously handed to
        ``on_site`` (phase, next site, partial accumulators) — together with
        restored MPS/env lists this continues an interrupted sweep with
        energies identical to the uninterrupted run (core/checkpoint.py).
        ``on_site(state)`` fires after every completed site update (pair
        optimization + env extension) with the resume state that would
        restart right after it, or ``None`` when the sweep just finished.
        The ``sweep.kill`` fault point fires after ``on_site`` — a test can
        checkpoint site k and die before site k+1, like a real crash.
        """
        n = self.n
        r = resume or {}
        energies: List[float] = list(r.get("energies", []))
        site_secs: List[float] = list(r.get("site_seconds", []))
        max_err = float(r.get("max_err", 0.0))
        svd_secs = float(r.get("svd_seconds", 0.0))
        env_secs = float(r.get("env_seconds", 0.0))
        secs_base = float(r.get("seconds", 0.0))
        dav = dict(r.get("davidson", {}))
        pair_retries = int(r.get("pair_retries", 0))
        phase = r.get("phase", "LR")
        start_j = int(r.get("j", 0 if phase == "LR" else n - 2))
        t0 = time.perf_counter()

        def _site(j: int, absorb: str):
            nonlocal max_err, svd_secs, env_secs, pair_retries
            ts = time.perf_counter()
            before = 0
            if isinstance(self.contract_fn, ContractionEngine):
                before = self.contract_fn.retries.get("pair", 0)
            lam, err, svd_dt, dinfo = self._optimize_pair(
                j, max_bond, cutoff, absorb=absorb
            )
            if isinstance(self.contract_fn, ContractionEngine):
                pair_retries += self.contract_fn.retries.get("pair", 0) - before
            te = time.perf_counter()
            if absorb == "right":
                self.left_envs[j + 1] = self._place(self._extend_left_env(j))
            else:
                self.right_envs[j] = self._place(self._extend_right_env(j))
            env_secs += time.perf_counter() - te
            energies.append(lam)
            site_secs.append(time.perf_counter() - ts)
            max_err = max(max_err, err)
            svd_secs += svd_dt
            dav["solves"] = dav.get("solves", 0) + 1
            dav["converged"] = dav.get("converged", 0) + int(dinfo.converged)
            dav["iterations"] = dav.get("iterations", 0) + dinfo.iterations
            dav["restarts"] = dav.get("restarts", 0) + dinfo.restarts
            dav["exhausted"] = dav.get("exhausted", 0) + int(dinfo.exhausted)

        def _after_site(state: Optional[Dict]):
            if on_site is not None:
                if state is not None:
                    state.update(
                        energies=list(energies),
                        site_seconds=list(site_secs),
                        max_err=max_err,
                        svd_seconds=svd_secs,
                        env_seconds=env_secs,
                        seconds=secs_base + time.perf_counter() - t0,
                        davidson=dict(dav),
                        pair_retries=pair_retries,
                    )
                on_site(state)
            if faults.fire("sweep.kill") is not None:
                raise FaultInjected(
                    "sweep.kill", "sweep killed after a site update"
                )

        if phase == "LR":
            for j in range(start_j, n - 1):  # left -> right
                _site(j, "right")
                nxt = (
                    {"phase": "LR", "j": j + 1}
                    if j + 1 < n - 1
                    else {"phase": "RL", "j": n - 2}
                )
                _after_site(nxt)
            start_j = n - 2

        for j in range(start_j, -1, -1):  # right -> left
            _site(j, "left")
            _after_site({"phase": "RL", "j": j - 1} if j > 0 else None)

        return SweepStats(
            energy=energies[-1],
            max_bond=self.mps.max_bond(),
            trunc_err=max_err,
            seconds=secs_base + time.perf_counter() - t0,
            site_seconds=site_secs,
            site_energies=energies,
            svd_seconds=svd_secs,
            env_seconds=env_secs,
            davidson_solves=dav.get("solves", 0),
            davidson_converged=dav.get("converged", 0),
            davidson_iterations=dav.get("iterations", 0),
            davidson_restarts=dav.get("restarts", 0),
            davidson_exhausted=dav.get("exhausted", 0),
            pair_retries=pair_retries,
        )
