"""Two-site DMRG sweeps (paper Sec. II-C, Fig. 1c-e).

Maintains left/right environments incrementally, optimizes each neighboring
pair with Davidson, splits with a blockwise truncated SVD absorbing the
singular values along the sweep direction, and supports all contraction
backends ("list", "dense", "csr", "batched", "auto") through the
plan-cached ``dist.ContractionEngine``.  Optional extras when the backend
is an engine (the default): a jitted planned matvec (``jit_matvec=True``)
with bucket-padded operands so it compiles once per quantized structure
(``pad_matvec``, defaulting to the jit flag), and a ``BlockShardPolicy``
that keeps MPS/MPO/environment blocks mesh-sharded, mirroring the paper's
distribute-every-block-over-all-processors layout.

The decomposition stage goes through the engine too (``svd_method``): the
planned batched SVD (``dist/decomp.py``) by default, the seed per-sector
loop with ``svd_method="unplanned"``, or the randomized path
("randomized"/"auto") — so ``_optimize_pair`` stays in device-land from the
matvec through the split, with one host sync per split for truncation.
``SweepStats.svd_seconds`` reports the stage's wall-clock per sweep.

The environment stage is the fourth and final pipeline stage under the
engine (``jit_env``, defaulting on for engines): each left/right env update
runs as ONE fused jitted call (``dist/envcore.py``) on power-of-two-padded
operands instead of three chained eager contractions, and ``_init_envs``
rebuilds the right environments as one planned right-to-left pass.
``jit_env=False`` (or a bare contractor) falls back to the seed
``extend_left`` / ``extend_right``; ``SweepStats.env_seconds`` carries the
stage's wall-clock per sweep.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

from ..dist.batch import pad_block_sparse, unpad_block_sparse
from ..dist.engine import ContractionEngine
from ..dist.shard import BlockShardPolicy
from ..tensor.blocksparse import (
    BlockSparseTensor,
    contract,
    flip_flow,
    svd_split_unplanned,
)
from .davidson import davidson
from .env import (
    extend_left,
    extend_right,
    get_contractor,
    left_edge,
    matvec_two_site,
    right_edge,
)
from .mps import MPS


@dataclasses.dataclass
class SweepStats:
    energy: float
    max_bond: int
    trunc_err: float
    seconds: float
    site_seconds: List[float]
    site_energies: List[float]
    # wall-clock of the decomposition stage (all svd_split calls) this sweep,
    # in seconds — the per-stage split bench_dist.py reports.  For the
    # planned path this includes the singular-value device sync, so it
    # reflects real SVD compute; the remainder of ``seconds`` is
    # contraction + Davidson + environment work.
    svd_seconds: float = 0.0
    # wall-clock of the environment stage (all left/right env updates) this
    # sweep, in seconds — fused jitted updates when ``jit_env`` is on, the
    # seed three-contraction path otherwise.  Host-side dispatch time (jax
    # is async), like the contraction engine's ``backend_seconds``.
    env_seconds: float = 0.0


class DMRGEngine:
    """Alternating two-site optimization with incremental environments."""

    def __init__(
        self,
        mps: MPS,
        mpo: List[BlockSparseTensor],
        algo: str = "list",
        davidson_iters: int = 2,
        seed: int = 0,
        jit_matvec: bool = False,
        pad_matvec: Optional[bool] = None,
        shard_policy: Optional[BlockShardPolicy] = None,
        engine: Optional[Callable] = None,
        svd_method: Optional[str] = None,
        jit_env: Optional[bool] = None,
    ):
        assert mps.n_sites == len(mpo)
        self.mps = mps
        self.mpo = mpo
        self.algo = algo
        self.contract_fn = engine if engine is not None else get_contractor(algo)
        self.jit_matvec = jit_matvec
        # bucket-pad the Davidson operands so the jitted matvec sees a small
        # set of block structures (compile-once); defaults to on iff jitting
        self.pad_matvec = jit_matvec if pad_matvec is None else pad_matvec
        # the MPO is immutable for the run — pad each site tensor once,
        # not on every pair optimization
        self._mpo_padded: List[Optional[BlockSparseTensor]] = [None] * len(mpo)
        if svd_method not in (None, "unplanned", "svd", "randomized", "auto"):
            raise ValueError(f"unknown svd_method: {svd_method!r}")
        if isinstance(self.contract_fn, ContractionEngine):
            # decomposition stage: engines route svd_split through their
            # planned DecompositionEngine ("svd" exact, "randomized", "auto"
            # cost model); "unplanned" forces the seed per-sector loop.  The
            # svd_method and shard_policy parameters are the single source of
            # truth: set them on the engine, or reset configuration left over
            # from a previous DMRGEngine that reused this engine instance
            self.svd_planned = svd_method != "unplanned"
            self.contract_fn.decomp.method = (
                svd_method if svd_method in ("svd", "randomized", "auto")
                else "svd"
            )
            self.contract_fn.policy = shard_policy
            # environment stage: fused plan-cached jitted updates
            # (dist/envcore.py) by default for engines; jit_env=False keeps
            # the seed extend_left/extend_right three-call path
            self.jit_env = True if jit_env is None else bool(jit_env)
        else:
            # bare contractors (the *_unplanned algos, or a plain callable
            # passed via engine=) have no gather step (sharded blocks would
            # deadlock eager CPU collectives), no jit pipeline and no planned
            # decomposition; fail loudly instead of hanging / silently
            # ignoring the flag
            backend = (
                f"algo={algo!r}" if engine is None
                else f"engine={type(engine).__name__}"
            )
            if shard_policy is not None:
                raise ValueError(
                    f"shard_policy requires a ContractionEngine backend, "
                    f"not {backend}"
                )
            if jit_matvec:
                raise ValueError(
                    f"jit_matvec requires a ContractionEngine backend, "
                    f"not {backend}"
                )
            if svd_method not in (None, "unplanned"):
                raise ValueError(
                    f"svd_method={svd_method!r} requires a ContractionEngine "
                    f"backend, not {backend}; bare contractors use the seed "
                    f"svd_split_unplanned"
                )
            if jit_env:
                raise ValueError(
                    f"jit_env requires a ContractionEngine backend, "
                    f"not {backend}; bare contractors use the seed "
                    f"extend_left/extend_right"
                )
            self.svd_planned = False
            self.jit_env = False
        if shard_policy is not None:
            self.mps.tensors = shard_policy.place_mps(self.mps.tensors)
            self.mpo = shard_policy.place_mps(self.mpo)
        self.shard_policy = shard_policy
        self.davidson_iters = davidson_iters
        self.seed = seed
        self.n = mps.n_sites
        self._init_envs()

    def _init_envs(self):
        n = self.n
        T, W = self.mps.tensors, self.mpo
        self.left_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        self.right_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        self.left_envs[0] = left_edge(T[0], W[0])
        self.right_envs[n - 1] = right_edge(T[n - 1], W[n - 1])
        # build right envs down to site 1 (first pair needs right_envs[1]) —
        # one planned right-to-left pass: fused jitted updates when jit_env
        for j in range(n - 2, 0, -1):
            self.right_envs[j] = self._place(self._extend_right_env(j))

    def _extend_left_env(self, j: int) -> BlockSparseTensor:
        """A_{j+1} from A_j: absorb site j into the left environment.

        Planned fused jitted update (``engine.env_update_left``) when
        ``jit_env`` is on; the seed three-contraction ``extend_left``
        otherwise (and always for bare contractors).
        """
        A, T, W = self.left_envs[j], self.mps.tensors[j], self.mpo[j]
        if self.jit_env:
            return self.contract_fn.env_update_left(
                A, T, W, mpo_padded=self._padded_mpo(j)
            )
        return extend_left(A, T, W, self.contract_fn)

    def _extend_right_env(self, j: int) -> BlockSparseTensor:
        """B_j from B_{j+1}: absorb site j+1 into the right environment."""
        B, T, W = self.right_envs[j + 1], self.mps.tensors[j + 1], self.mpo[j + 1]
        if self.jit_env:
            return self.contract_fn.env_update_right(
                B, T, W, mpo_padded=self._padded_mpo(j + 1)
            )
        return extend_right(B, T, W, self.contract_fn)

    def _padded_mpo(self, j: int) -> BlockSparseTensor:
        if self._mpo_padded[j] is None:
            self._mpo_padded[j] = pad_block_sparse(self.mpo[j])
        return self._mpo_padded[j]

    def _place(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Mesh-shard a stored tensor (env / site) when a policy is attached."""
        return t if self.shard_policy is None else self.shard_policy.place(t)

    def _optimize_pair(self, j: int, max_bond: int, cutoff: float, absorb: str):
        T, W = self.mps.tensors, self.mpo
        A, B = self.left_envs[j], self.right_envs[j + 1]
        theta = self.contract_fn(T[j], T[j + 1], ((2,), (0,)))

        pad = (
            self.pad_matvec and isinstance(self.contract_fn, ContractionEngine)
        )
        if pad:
            # round every sector dim up to a power of two: zero-padding is
            # exact (padded operator entries are zero) and quantizes the
            # traced structure, so the jitted matvec compiles once per
            # bucketed structure instead of once per site per sweep
            orig_indices = theta.indices
            A, B = pad_block_sparse(A), pad_block_sparse(B)
            Wjp, Wj1p = self._padded_mpo(j), self._padded_mpo(j + 1)
            theta = pad_block_sparse(theta)
        else:
            Wjp, Wj1p = W[j], W[j + 1]

        if isinstance(self.contract_fn, ContractionEngine):
            mv = self.contract_fn.matvec_fn(
                A, Wjp, Wj1p, B, jit=self.jit_matvec
            )
        else:
            def mv(x):
                return matvec_two_site(A, Wjp, Wj1p, B, x, self.contract_fn)

        lam, theta = davidson(
            mv, theta, n_iter=self.davidson_iters, seed=self.seed + j
        )
        if pad:
            theta = unpad_block_sparse(theta, orig_indices)
        # decomposition stage: planned engines stay in device-land — one
        # batched SVD core call plus a single singular-value sync for the
        # global truncation — while the seed path loops sectors on host
        t_svd = time.perf_counter()
        if self.svd_planned:
            U, V, _, err = self.contract_fn.svd_split(
                theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb
            )
        else:
            U, V, _, err = svd_split_unplanned(
                theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb
            )
        svd_dt = time.perf_counter() - t_svd
        T[j] = self._place(flip_flow(U, 2))
        T[j + 1] = self._place(flip_flow(V, 0))
        return lam, err, svd_dt

    def sweep(self, max_bond: int, cutoff: float = 1e-12) -> SweepStats:
        """One full left-to-right + right-to-left sweep; returns stats."""
        T, W = self.mps.tensors, self.mpo
        n = self.n
        energies, site_secs = [], []
        max_err = 0.0
        svd_secs = 0.0
        env_secs = 0.0
        t0 = time.perf_counter()

        for j in range(n - 1):  # left -> right
            ts = time.perf_counter()
            lam, err, svd_dt = self._optimize_pair(j, max_bond, cutoff, absorb="right")
            te = time.perf_counter()
            self.left_envs[j + 1] = self._place(self._extend_left_env(j))
            env_secs += time.perf_counter() - te
            energies.append(lam)
            site_secs.append(time.perf_counter() - ts)
            max_err = max(max_err, err)
            svd_secs += svd_dt

        for j in range(n - 2, -1, -1):  # right -> left
            ts = time.perf_counter()
            lam, err, svd_dt = self._optimize_pair(j, max_bond, cutoff, absorb="left")
            te = time.perf_counter()
            self.right_envs[j] = self._place(self._extend_right_env(j))
            env_secs += time.perf_counter() - te
            energies.append(lam)
            site_secs.append(time.perf_counter() - ts)
            max_err = max(max_err, err)
            svd_secs += svd_dt

        return SweepStats(
            energy=energies[-1],
            max_bond=self.mps.max_bond(),
            trunc_err=max_err,
            seconds=time.perf_counter() - t0,
            site_seconds=site_secs,
            site_energies=energies,
            svd_seconds=svd_secs,
            env_seconds=env_secs,
        )
