"""Left/right environment tensors (paper Fig. 1d and Sec. II-C).

Environment index convention (bra, mpo, ket):
  A_j (left env, sites < j):  i: IN (bra bond), k: OUT (mpo bond), l: OUT (ket bond)
  B_j (right env, sites > j): i: OUT, k: IN, l: IN
so that every contraction with site/MPO/bra tensors type-checks by flow.

The contraction backend is pluggable: "list" (paper Alg. 2), "dense"
(sparse-dense), "csr" (sparse-sparse, TPU block-CSR adaptation), "batched"
(shape-bucketed stacked GEMMs, dist/batch.py), or "auto" (cost-model
choice).  All of them now execute through the plan-cached
``dist.ContractionEngine``; ``get_contractor`` is kept as a thin compat shim
over it.  The ``*_unplanned`` names expose the seed per-call algorithms for
A/B benchmarking.

``extend_left`` / ``extend_right`` here are the seed environment updates —
three chained ``contract_fn`` calls — kept verbatim as the bare-contract
fallback and the reference the fused environment engine
(``dist/envcore.py``, ``jit_env`` in ``core/sweep.py``) is tested against
block-for-block.
"""
from __future__ import annotations

from typing import Callable, List

import jax.numpy as jnp

from ..dist.engine import ContractionEngine
from ..tensor.blocksparse import BlockSparseTensor, contract, contract_dense
from ..tensor.block_csr import contract_block_csr
from ..tensor.qn import IN, Index, OUT


def get_contractor(algo: str) -> Callable:
    """Compat shim: algorithm name -> plan-cached ContractionEngine.

    The returned object is callable as ``fn(a, b, axes)`` exactly like the
    bare contraction functions it replaces; sweep code that wants the engine
    extras (jitted matvec, sharding policy, the planned ``svd_split``
    decomposition stage, the fused ``env_update_left/right`` environment
    stage, stats) can use them when present.  Engine-backed
    names carry the <1e-10 seed-equality guarantee of ``dist.engine``; the
    ``*_unplanned`` names ARE the seed algorithms.
    """
    if algo in ("list", "dense", "batched"):
        return ContractionEngine(backend=algo)
    if algo == "csr":
        return ContractionEngine(backend="csr", interpret=True, use_kernel=True)
    if algo == "csr_ref":
        return ContractionEngine(backend="csr", use_kernel=False)
    if algo in ("auto", "planned"):
        return ContractionEngine(backend="auto")
    # seed per-call algorithms, kept for A/B comparison in bench_dist
    if algo == "list_unplanned":
        return contract
    if algo == "dense_unplanned":
        return contract_dense
    if algo == "csr_unplanned":
        return lambda a, b, axes: contract_block_csr(a, b, axes, use_kernel=False)
    raise ValueError(f"unknown contraction algorithm: {algo}")


def left_edge(mps_t0: BlockSparseTensor, mpo_w0: BlockSparseTensor) -> BlockSparseTensor:
    lq = mps_t0.indices[0].sectors  # ((q0, 1),)
    kq = mpo_w0.indices[0].sectors
    i = Index(lq, IN, "env_i")
    k = Index(kq, OUT, "env_k")
    l = Index(lq, OUT, "env_l")
    return BlockSparseTensor([i, k, l], {(0, 0, 0): jnp.ones((1, 1, 1), mps_t0.dtype)})


def right_edge(mps_tn: BlockSparseTensor, mpo_wn: BlockSparseTensor) -> BlockSparseTensor:
    rq = mps_tn.indices[2].sectors
    kq = mpo_wn.indices[3].sectors
    i = Index(rq, OUT, "env_i")
    k = Index(kq, IN, "env_k")
    l = Index(rq, IN, "env_l")
    return BlockSparseTensor([i, k, l], {(0, 0, 0): jnp.ones((1, 1, 1), mps_tn.dtype)})


def extend_left(
    A: BlockSparseTensor,
    T: BlockSparseTensor,
    W: BlockSparseTensor,
    contract_fn: Callable = contract,
) -> BlockSparseTensor:
    """A' = A . T_j . W_j . conj(T_j), cost O(m^3 k d) + O(m^2 k^2 d^2)."""
    bra = T.conj()
    tmp = contract_fn(A, T, ((2,), (0,)))            # (i, k, s, r)
    tmp = contract_fn(tmp, W, ((1, 2), (0, 2)))      # (i, r, so, k')
    out = contract_fn(bra, tmp, ((0, 1), (0, 2)))    # (r_bra, r_ket, k')
    return out.transpose((0, 2, 1))                  # (i', k', l')


def extend_right(
    B: BlockSparseTensor,
    T: BlockSparseTensor,
    W: BlockSparseTensor,
    contract_fn: Callable = contract,
) -> BlockSparseTensor:
    """B' = T_j . W_j . conj(T_j) . B (absorb site j into the right env)."""
    bra = T.conj()
    tmp = contract_fn(T, B, ((2,), (2,)))            # (l, s, i', k')
    tmp = contract_fn(tmp, W, ((3, 1), (3, 2)))      # (l, i', lw, so)
    out = contract_fn(tmp, bra, ((1, 3), (2, 1)))    # (l, lw, l_bra)
    return out.transpose((2, 1, 0))                  # (i', k', l')


def matvec_two_site(
    A: BlockSparseTensor,
    Wj: BlockSparseTensor,
    Wj1: BlockSparseTensor,
    B: BlockSparseTensor,
    x: BlockSparseTensor,
    contract_fn: Callable = contract,
) -> BlockSparseTensor:
    """y = K x with K = A . W_j . W_{j+1} . B (paper Fig. 1d), O(m^3 k d)."""
    t = contract_fn(A, x, ((2,), (0,)))              # (i, k, s1, s2, r)
    t = contract_fn(t, Wj, ((1, 2), (0, 2)))         # (i, s2, r, so1, k1)
    t = contract_fn(t, Wj1, ((4, 1), (0, 2)))        # (i, r, so1, so2, k2)
    t = contract_fn(t, B, ((4, 1), (1, 2)))          # (i, so1, so2, i')
    return t


def expectation(
    mps_tensors: List[BlockSparseTensor],
    mpo: List[BlockSparseTensor],
    contract_fn: Callable = contract,
):
    """<psi|H|psi> via a full left-to-right environment sweep."""
    A = left_edge(mps_tensors[0], mpo[0])
    for T, W in zip(mps_tensors, mpo):
        A = extend_left(A, T, W, contract_fn)
    acc = 0.0
    for b in A.blocks.values():
        acc = acc + jnp.sum(b)
    return jnp.real(acc)
