"""Sweep checkpoint/resume: crash-durable DMRG state (DESIGN.md 3.8).

A production ground-state solve is hours of sweeping; a node failure at
sweep 40 of 50 should cost one site update, not the run.  This module
serializes everything a mid-sweep resume needs to continue with energies
*identical* to the uninterrupted run (<1e-10; in practice bit-identical):

- the MPS tensors (the optimization state proper),
- BOTH environment lists, exactly as they stood — mid-LR-sweep the right
  environments are partially stale leftovers of the previous half-sweep, a
  state a fresh right-to-left rebuild cannot reproduce, so restoring the
  serialized copies is what makes resume exact rather than approximate,
- the schedule position (bond index, sweep index) and the in-sweep resume
  dict (phase, next site, partial accumulators) produced by
  ``DMRGEngine.sweep``'s ``on_site`` callback,
- completed per-sweep stats and the Davidson seed.

Determinism does the rest: Davidson start vectors derive from the MPS,
restart randomness is seeded per site (``seed + j``), and truncation
decisions replay from the same singular values.

Format: stdlib pickle of a dict whose leaves are numpy arrays and plain
Python structure (``Index`` is a frozen dataclass of int tuples) — no jax
arrays are pickled, so checkpoints are portable across devices/backends.
Writes are atomic (tmp file + ``os.replace``) and pruned to the newest
``keep`` files, so a crash mid-write can never corrupt the latest good
checkpoint.  Pickle is trusted-input-only, like any pickle; checkpoints
are local run state, not a wire format.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import re
import tempfile
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor

CHECKPOINT_VERSION = 1
_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.pkl$")


# ---------------------------------------------------------- tensor (de)hydrate
def tensor_state(t: Optional[BlockSparseTensor]):
    """Picklable form of a block-sparse tensor (None passes through).

    Blocks are pulled to host numpy via ``jax.device_get`` — an exact bit
    copy, which is what the resume-equality guarantee rests on.
    """
    if t is None:
        return None
    return (
        t.indices,
        t.charge,
        {k: np.asarray(jax.device_get(b)) for k, b in t.blocks.items()},
    )


def tensor_restore(state) -> Optional[BlockSparseTensor]:
    """Inverse of ``tensor_state`` (numpy -> device arrays, exact copy)."""
    if state is None:
        return None
    indices, charge, blocks = state
    return BlockSparseTensor(
        indices, {k: jnp.asarray(v) for k, v in blocks.items()}, charge
    )


class CheckpointManager:
    """Atomic, pruned pickle checkpoints in one directory.

    Parameters
    ----------
    directory: where ``ckpt_<step>.pkl`` files live; created if missing.
    every: save cadence in site updates (``maybe_save`` persists when the
        state's step counter is a multiple of this; the driver also saves
        unconditionally at sweep boundaries).
    keep: newest checkpoints retained after each save (>= 1).  Two is the
        classic crash-safety margin: even if the host dies the instant
        after ``os.replace``, the previous good file is still there.
    """

    def __init__(self, directory: str, every: int = 1, keep: int = 2):
        assert every >= 1 and keep >= 1
        self.directory = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self.saves = 0

    # ------------------------------------------------------------------ save
    def save(self, state: Dict) -> str:
        """Atomically persist ``state`` (keyed by ``state["step"]``)."""
        state = dict(state)
        state["version"] = CHECKPOINT_VERSION
        path = os.path.join(
            self.directory, f"ckpt_{int(state['step']):08d}.pkl"
        )
        fd, tmp = tempfile.mkstemp(
            dir=self.directory, prefix=".ckpt_tmp_", suffix=".pkl"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(state, f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)  # atomic: readers see old or new, never torn
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.saves += 1
        self._prune()
        return path

    def maybe_save(self, state: Dict) -> Optional[str]:
        """Save iff the step counter hits the cadence; returns the path."""
        if int(state["step"]) % self.every == 0:
            return self.save(state)
        return None

    # ------------------------------------------------------------------ load
    def _list(self) -> List[str]:
        names = sorted(
            n for n in os.listdir(self.directory) if _CKPT_RE.match(n)
        )
        return [os.path.join(self.directory, n) for n in names]

    def load_latest(self) -> Optional[Dict]:
        """Newest readable checkpoint, or None (fresh start).

        Walks newest-to-oldest so a truncated file left by a crash mid-write
        under a non-atomic filesystem degrades to the previous good one.
        """
        for path in reversed(self._list()):
            try:
                with open(path, "rb") as f:
                    state = pickle.load(f)
            except (OSError, pickle.UnpicklingError, EOFError):
                continue
            if state.get("version") != CHECKPOINT_VERSION:
                continue
            return state
        return None

    def _prune(self) -> None:
        for path in self._list()[: -self.keep]:
            try:
                os.unlink(path)
            except OSError:
                pass


# ------------------------------------------------------- driver state helpers
def pack_run_state(
    *,
    step: int,
    bond_idx: int,
    sweep_idx: int,
    sweep_resume: Optional[Dict],
    mps_tensors,
    left_envs,
    right_envs,
    stats,
    seed: int,
) -> Dict:
    """Full ``run_dmrg`` state -> one picklable dict (see module docstring)."""
    return {
        "step": step,
        "bond_idx": bond_idx,
        "sweep_idx": sweep_idx,
        "sweep_resume": sweep_resume,
        "mps": [tensor_state(t) for t in mps_tensors],
        "left_envs": [tensor_state(t) for t in left_envs],
        "right_envs": [tensor_state(t) for t in right_envs],
        "stats": [dataclasses.asdict(s) for s in stats],
        "seed": seed,
    }


def unpack_envs(state: Dict):
    """Restored (left_envs, right_envs) lists for ``DMRGEngine``."""
    return (
        [tensor_restore(s) for s in state["left_envs"]],
        [tensor_restore(s) for s in state["right_envs"]],
    )
