"""MPO construction from operator terms (AutoMPO-style, paper Sec. V).

Finite-state-machine construction: each MPO bond carries a set of states —
READY (no term started), DONE (term completed, identity onward), and one
partial state per term currently "in flight" — grouped into quantum-number
sectors by the accumulated operator charge.  Long-range terms thread a
connector operator (Id, or the JW parity F for fermionic hops) through
intermediate sites.  ``compress_mpo`` then SVD-truncates every bond (the
paper compresses each order-4 tensor of H "via SVD to a 1e-13 cutoff,
resulting in an MPO with a bond dimension k=26" for the electron system).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor, contract, flip_flow, svd_split
from ..tensor.qn import Charge, IN, Index, OUT, qzero
from .opterm import OpTerm
from .siteops import LocalSpace

READY = ("R",)
DONE = ("D",)


def _state_charge(space: LocalSpace, term: OpTerm, p: int) -> Charge:
    """Charge of partial state (term, p ops placed): Q = -sum dq(first p ops)."""
    nq = len(space.state_charges[0])
    q = list(qzero(nq))
    for name, _ in term.ops[:p]:
        dq = space.op_charge(name)
        for i in range(nq):
            q[i] -= dq[i]
    return tuple(q)


def build_mpo(
    space: LocalSpace, terms: Sequence[OpTerm], n_sites: int, dtype=jnp.float64
) -> List[BlockSparseTensor]:
    """Exact (uncompressed) FSM MPO for the term list."""
    nq = len(space.state_charges[0])
    zero = qzero(nq)

    # ---- bond state sets: bond b sits between sites b and b+1, b in -1..N-1
    bond_states: List[List[tuple]] = []
    for b in range(-1, n_sites):
        states: List[tuple] = []
        if b < n_sites - 1:
            states.append(READY)
        for t_id, t in enumerate(terms):
            first, last = t.sites[0], t.sites[-1]
            if first <= b < last:  # term strictly spans this bond
                p = sum(1 for s in t.sites if s <= b)
                states.append(("P", t_id, p))
        if b >= 0:
            states.append(DONE)
        bond_states.append(states)

    def charge_of(state: tuple) -> Charge:
        if state in (READY, DONE):
            return zero
        _, t_id, p = state
        return _state_charge(space, terms[t_id], p)

    # ---- index construction: group states by charge, remember offsets
    def make_bond_index(states: List[tuple], flow: int):
        by_q: Dict[Charge, List[tuple]] = {}
        for s in states:
            by_q.setdefault(charge_of(s), []).append(s)
        charges = sorted(by_q.keys())
        ix = Index(tuple((q, len(by_q[q])) for q in charges), flow, "mpo")
        loc = {}
        for si, q in enumerate(charges):
            for off, s in enumerate(by_q[q]):
                loc[s] = (si, off)
        return ix, loc

    phys_out = space.index  # flow OUT
    phys_in = space.index.dual()
    # physical sector lookup: state s -> sector position (each state is its own sector)
    phys_sector = {s: s for s in range(space.d)}

    mpo: List[BlockSparseTensor] = []
    for j in range(n_sites):
        # bond b is stored at position b+1; left bond of site j is b=j-1
        lix, lloc = make_bond_index(bond_states[j], IN)
        rix, rloc = make_bond_index(bond_states[j + 1], OUT)

        # transitions: (l_state, r_state) -> d x d matrix
        trans: Dict[Tuple[tuple, tuple], np.ndarray] = {}

        def add(ls, rs, mat):
            if (ls, rs) in trans:
                trans[(ls, rs)] = trans[(ls, rs)] + mat
            else:
                trans[(ls, rs)] = np.array(mat, dtype=np.complex128 if np.iscomplexobj(mat) else np.float64)

        lstates = bond_states[j]
        rstates = set(bond_states[j + 1])
        if READY in lstates and READY in rstates:
            add(READY, READY, space.ops["Id"])
        if DONE in lstates and DONE in rstates:
            add(DONE, DONE, space.ops["Id"])
        for t_id, t in enumerate(terms):
            sites = t.sites
            first, last = sites[0], sites[-1]
            if j < first or j > last:
                continue
            if j == first:
                ls = READY
                op = np.asarray(space.ops[t.ops[0][0]]) * t.coef
                rs = DONE if len(sites) == 1 else ("P", t_id, 1)
                if ls in lstates and rs in rstates:
                    add(ls, rs, op)
                continue
            p = sum(1 for s in sites if s < j)  # ops placed strictly left of j
            ls = ("P", t_id, p)
            if ls not in lstates:
                continue
            if j in sites:
                op = np.asarray(space.ops[t.ops[p][0]])
                rs = DONE if p + 1 == len(sites) else ("P", t_id, p + 1)
            else:
                op = np.asarray(space.ops[t.connector])
                rs = ("P", t_id, p)
            if rs in rstates:
                add(ls, rs, op)

        # ---- fill blocks
        blocks: Dict[tuple, np.ndarray] = {}
        for (ls, rs), mat in trans.items():
            lsec, loff = lloc[ls]
            rsec, roff = rloc[rs]
            for o in range(space.d):
                for i in range(space.d):
                    v = mat[o, i]
                    if abs(v) < 1e-15:
                        continue
                    key = (lsec, phys_sector[o], phys_sector[i], rsec)
                    if key not in blocks:
                        blocks[key] = np.zeros(
                            (lix.sector_dim(lsec), 1, 1, rix.sector_dim(rsec)),
                            dtype=np.float64,
                        )
                    blocks[key][loff, 0, 0, roff] += float(np.real(v))
        w = BlockSparseTensor(
            [lix, phys_out, phys_in, rix],
            {k: jnp.asarray(b, dtype) for k, b in blocks.items()},
        )
        w.check()
        mpo.append(w)
    return mpo


def mpo_bond_dims(mpo: List[BlockSparseTensor]) -> List[int]:
    return [w.indices[3].dim for w in mpo[:-1]]


def compress_mpo(
    mpo: List[BlockSparseTensor], cutoff: float = 1e-13, max_bond: int = 10**9
) -> List[BlockSparseTensor]:
    """SVD-compress every MPO bond (L->R then R->L), preserving l:IN / r:OUT."""
    mpo = list(mpo)
    n = len(mpo)
    for sweep_dir in ("lr", "rl"):
        rng = range(n - 1) if sweep_dir == "lr" else range(n - 2, -1, -1)
        for j in rng:
            theta = contract(mpo[j], mpo[j + 1], axes=((3,), (0,)))
            # modes: (l, o_j, i_j, o_j1, i_j1, r)
            absorb = "right" if sweep_dir == "lr" else "left"
            U, V, _, _ = svd_split(theta, 3, max_bond=max_bond, cutoff=cutoff, absorb=absorb)
            U = flip_flow(U, 3)   # bond IN -> OUT on U's last mode
            V = flip_flow(V, 0)   # bond OUT -> IN on V's first mode
            mpo[j], mpo[j + 1] = U, V
    return mpo
