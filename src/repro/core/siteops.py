"""Local Hilbert spaces and operators for the paper's two benchmark systems.

*spins*     : d=2 spin-1/2, one U(1) charge (2*Sz)                (Sec. V, J1-J2)
*electrons* : d=4 Hubbard site, two U(1) charges (N, 2*Sz)        (Sec. V)

Operators are plain numpy matrices in the sector-ordered basis; the physical
``Index`` orders sectors exactly as the basis states, so <out|op|in> maps to
block-sparse entries directly.  Fermionic signs use the Jordan-Wigner parity
operator F; within-site species order is c†_up before c†_dn.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from ..tensor.qn import Charge, IN, Index, OUT


@dataclasses.dataclass
class LocalSpace:
    name: str
    index: Index                    # physical index, flow OUT, one state per basis vector
    ops: Dict[str, np.ndarray]      # dense d x d matrices <out|op|in>
    state_charges: Tuple[Charge, ...]  # charge of each basis state

    @property
    def d(self) -> int:
        return self.index.dim

    def op_charge(self, name: str) -> Charge:
        """Charge transferred by the operator (must be homogeneous)."""
        op = self.ops[name]
        dq = None
        for o in range(self.d):
            for i in range(self.d):
                if abs(op[o, i]) > 1e-14:
                    q = tuple(a - b for a, b in zip(self.state_charges[o], self.state_charges[i]))
                    assert dq is None or dq == q, f"{name} is not charge-homogeneous"
                    dq = q
        return dq if dq is not None else (0,) * len(self.state_charges[0])


def spin_half_space() -> LocalSpace:
    """Basis |up>, |down>; charge = 2*Sz in {+1, -1}."""
    sz = np.diag([0.5, -0.5])
    sp = np.array([[0.0, 1.0], [0.0, 0.0]])  # S+ |down> = |up>
    sm = sp.T
    eye = np.eye(2)
    index = Index((((1,), 1), ((-1,), 1)), OUT, "spin")
    return LocalSpace(
        "spin_half",
        index,
        {"Id": eye, "Sz": sz, "S+": sp, "S-": sm},
        (((1,)), ((-1,))),
    )


def electron_space() -> LocalSpace:
    """Basis |0>, |up>, |dn>, |updn>; charges (N, 2*Sz).

    |updn> := c†_up c†_dn |0>.  Local annihilators (JW-resolved within site):
      a_up |up> = |0>,   a_up |updn> =  |dn>
      a_dn |dn> = |0>,   a_dn |updn> = -|up>
    F = (-1)^n = diag(1, -1, -1, 1).
    """
    d = 4
    a_up = np.zeros((d, d))
    a_up[0, 1] = 1.0
    a_up[2, 3] = 1.0
    a_dn = np.zeros((d, d))
    a_dn[0, 2] = 1.0
    a_dn[1, 3] = -1.0
    adag_up = a_up.T
    adag_dn = a_dn.T
    F = np.diag([1.0, -1.0, -1.0, 1.0])
    n_up = adag_up @ a_up
    n_dn = adag_dn @ a_dn
    eye = np.eye(d)
    state_charges = ((0, 0), (1, 1), (1, -1), (2, 0))
    index = Index(
        (((0, 0), 1), ((1, 1), 1), ((1, -1), 1), ((2, 0), 1)), OUT, "electron"
    )
    ops = {
        "Id": eye,
        "F": F,
        "a_up": a_up,
        "a_dn": a_dn,
        "adag_up": adag_up,
        "adag_dn": adag_dn,
        "n_up": n_up,
        "n_dn": n_dn,
        "ntot": n_up + n_dn,
        "nupdn": n_up @ n_dn,
        # JW-dressed hopping endpoints: c†_i c_j (i<j) = (a†_i F_i) [F] (a_j),
        # c†_j c_i (i<j) = (F_i a_i) [F] (a†_j);  see core/mpo.py
        "adagF_up": adag_up @ F,
        "adagF_dn": adag_dn @ F,
        "Fa_up": F @ a_up,
        "Fa_dn": F @ a_dn,
    }
    return LocalSpace("electron", index, ops, state_charges)
