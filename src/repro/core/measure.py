"""Observable measurement on MPS: single-site expectations and two-point
correlation functions (what the paper's physics studies consume downstream —
e.g. spin-spin correlations for the J1-J2 phase diagram).

Pure transfer-matrix contractions on the block-sparse substrate; cost
O(N m^3 d) per observable sweep, same scaling as one environment build.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor, contract
from ..tensor.qn import IN, Index, OUT
from .mps import MPS
from .siteops import LocalSpace


def _op_tensor(space: LocalSpace, name: str) -> np.ndarray:
    return np.asarray(space.ops[name])


def _apply_op(T: BlockSparseTensor, space: LocalSpace, op: np.ndarray
              ) -> BlockSparseTensor:
    """Contract a local operator into the physical leg.  Charged operators
    (S+, c†, ...) shift the tensor charge so conservation still holds and
    the intermediate environments carry the charge between the two points."""
    from ..tensor.qn import qadd

    blocks = {}
    dq = None
    for key, blk in T.blocks.items():
        s = key[1]
        for so in range(space.d):
            v = op[so, s]
            if abs(v) < 1e-15:
                continue
            nk = (key[0], so, key[2])
            add = float(v) * blk
            blocks[nk] = blocks[nk] + add if nk in blocks else add
            dq = tuple(a - b for a, b in zip(space.state_charges[so],
                                             space.state_charges[s]))
    charge = T.charge if dq is None else qadd(T.charge, dq)
    return BlockSparseTensor(T.indices, blocks, charge)


def _transfer(env: BlockSparseTensor, T: BlockSparseTensor,
              Top: BlockSparseTensor) -> BlockSparseTensor:
    """env (bra_bond, ket_bond) -> next bond, with possibly-modified ket."""
    t = contract(env, Top, axes=((1,), (0,)))          # (bra, s, r)
    return contract(T.conj(), t, axes=((0, 1), (0, 1)))  # (r_bra, r_ket)


def _edge(T0: BlockSparseTensor) -> BlockSparseTensor:
    lq = T0.indices[0].sectors
    return BlockSparseTensor(
        [Index(lq, IN, "e_bra"), Index(lq, OUT, "e_ket")],
        {(0, 0): jnp.ones((1, 1), T0.dtype)},
    )


def _close(env: BlockSparseTensor) -> float:
    acc = 0.0
    for b in env.blocks.values():
        acc = acc + jnp.sum(b)
    return float(jnp.real(acc))


def site_expectation(mps: MPS, space: LocalSpace, opname: str, site: int
                     ) -> float:
    """<psi| op_site |psi> / <psi|psi>."""
    op = _op_tensor(space, opname)
    env = _edge(mps.tensors[0])
    norm_env = _edge(mps.tensors[0])
    for j, T in enumerate(mps.tensors):
        Top = _apply_op(T, space, op) if j == site else T
        env = _transfer(env, T, Top)
        norm_env = _transfer(norm_env, T, T)
    return _close(env) / _close(norm_env)


def correlation(mps: MPS, space: LocalSpace, op1: str, op2: str,
                i: int, j: int) -> float:
    """<psi| op1_i op2_j |psi> / <psi|psi> for i < j (connected part NOT
    subtracted)."""
    assert i < j
    o1, o2 = _op_tensor(space, op1), _op_tensor(space, op2)
    env = _edge(mps.tensors[0])
    norm_env = _edge(mps.tensors[0])
    for s, T in enumerate(mps.tensors):
        if s == i:
            Top = _apply_op(T, space, o1)
        elif s == j:
            Top = _apply_op(T, space, o2)
        else:
            Top = T
        env = _transfer(env, T, Top)
        norm_env = _transfer(norm_env, T, T)
    return _close(env) / _close(norm_env)


def correlation_profile(mps: MPS, space: LocalSpace, op1: str, op2: str,
                        ref: int = 0) -> List[Tuple[int, float]]:
    """C(r) = <op1_ref op2_(ref+r)> for all r > 0."""
    return [(j - ref, correlation(mps, space, op1, op2, ref, j))
            for j in range(ref + 1, mps.n_sites)]
