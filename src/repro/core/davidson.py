"""Davidson eigensolver (paper Alg. 1).

Follows the paper's ITensor-derived implementation: no preconditioning,
modified Gram-Schmidt re-orthogonalization with randomization on breakdown,
small subspace (size 2 during production sweeps).  Operates directly on
block-sparse tensors; the matvec is the environment contraction of Fig. 1d.

The subspace update is batched: each iteration fetches the new column of
the Rayleigh matrix M[j, i] = <v_j | A v_i> AND the new column of the Gram
matrix W[j, i] = <A v_j | A v_i> in ONE fused device call (a stacked reduce
followed by a single host sync), instead of one blocking
``float(np.asarray(...))`` round-trip per inner product.  The residual norm
comes for free from the Gram identity ||A x - lam x||^2 = s^T W s - lam^2
(V orthonormal, s the Ritz coefficients, s^T M s = lam), so convergence is
checked without another sync.  The identity cancels catastrophically once
the true residual approaches sqrt(eps)·|lam| — there the estimate is pure
noise and the break decision would flip on last-ulp input differences — so
below that floor the exact residual-vector norm is measured instead (one
extra sync, only in the already-converged regime), keeping the convergence
branch as ulp-stable as the seed implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist import faults
from ..dist.faults import NumericalHealthError
from ..tensor.blocksparse import BlockSparseTensor

# Shared numerical thresholds — the batched multi-problem mirror
# (repro/serve/multicore.py) must make bit-identical break decisions, so it
# imports these instead of re-stating the literals.
GRAM_NOISE_FLOOR = 1e-12   # scale factor for the Gram-identity noise floor
GS_BREAKDOWN_TOL = 1e-12   # Gram-Schmidt breakdown threshold factor


@dataclasses.dataclass
class DavidsonInfo:
    """Health record of one Davidson solve (no more silent break-outs).

    ``converged``: the residual norm dropped below ``tol`` before the
    iteration budget ran out.  Production sweeps with small ``n_iter``
    typically stop on the budget without ever measuring the final residual,
    so ``converged=False`` there means "unknown", not "diverged" — the
    interesting counters are ``restarts`` (Gram-Schmidt breakdowns answered
    with a seeded random restart) and ``exhausted`` (the restart ALSO broke
    down: the Krylov subspace is exhausted and the solve accepted the
    current Ritz pair early, which the seed implementation did silently).
    """

    converged: bool = False
    iterations: int = 0
    restarts: int = 0
    exhausted: bool = False


def _new_columns(V, AV, i) -> np.ndarray:
    """Fetch M[j, i] and W[j, i] for j <= i in one device round-trip."""
    vals = [V[j].inner(AV[i]) for j in range(i + 1)]
    vals += [AV[j].inner(AV[i]) for j in range(i + 1)]
    return np.real(np.asarray(jax.device_get(jnp.stack(vals))))


def davidson(
    matvec: Callable[[BlockSparseTensor], BlockSparseTensor],
    x0: BlockSparseTensor,
    n_iter: int = 2,
    tol: float = 1e-10,
    seed: int = 0,
) -> Tuple[float, BlockSparseTensor, DavidsonInfo]:
    """Return (smallest eigenvalue, eigenvector approximation, health info).

    Health guard: the Rayleigh-Ritz column read is the solve's one existing
    host sync per iteration — a non-finite entry there (a NaN-poisoned
    matvec, an overflowed contraction) would otherwise propagate silently
    into the eigh and out through the MPS, so it raises
    ``NumericalHealthError(stage="davidson")`` at zero extra sync cost.
    """
    info = DavidsonInfo()
    # injected non-convergence: suppress the residual break so the solve
    # runs its full budget and honestly reports converged=False
    force_no_converge = faults.fire("davidson.no_converge") is not None
    nrm = x0.norm()
    x = x0.scale(1.0 / nrm)
    V = [x]
    AV = [matvec(x)]
    if n_iter <= 0:
        lam = float(np.real(np.asarray(V[0].inner(AV[0]))))
        if not np.isfinite(lam):
            raise NumericalHealthError(
                "non-finite Rayleigh quotient", stage="davidson"
            )
        return lam, x, info

    dim = n_iter + 1
    M = np.zeros((dim, dim))  # <v_j | A v_i>
    W = np.zeros((dim, dim))  # <A v_j | A v_i>
    lam, x = 0.0, V[0]

    for i in range(n_iter):
        cols = _new_columns(V, AV, i)
        if not np.isfinite(cols).all():
            raise NumericalHealthError(
                f"non-finite Rayleigh-Ritz entries at iteration {i}",
                stage="davidson",
            )
        info.iterations = i + 1
        M[: i + 1, i] = M[i, : i + 1] = cols[: i + 1]
        W[: i + 1, i] = W[i, : i + 1] = cols[i + 1 :]
        evals, evecs = np.linalg.eigh(M[: i + 1, : i + 1])
        lam, s = float(evals[0]), evecs[:, 0]

        # Ritz vector (device-side; no sync)
        x = V[0].scale(s[0])
        for j in range(1, i + 1):
            x = x + V[j].scale(s[j])
        if i == n_iter - 1:
            break

        # residual q = A x - lam x (device-side), with its norm from the
        # Gram identity when that is well above the cancellation noise
        # floor, and measured exactly otherwise (converged regime only)
        q = AV[0].scale(s[0])
        for j in range(1, i + 1):
            q = q + AV[j].scale(s[j])
        q = q - x.scale(lam)
        qn2_gram = float(s @ W[: i + 1, : i + 1] @ s - lam * lam)
        noise_floor = GRAM_NOISE_FLOOR * max(1.0, lam * lam)
        if qn2_gram > noise_floor:
            qn = float(np.sqrt(qn2_gram))
        else:
            qn = float(np.asarray(q.norm()))
        if qn < tol and not force_no_converge:
            info.converged = True
            break

        # modified Gram-Schmidt vs all v_j, randomize on breakdown (paper)
        for j in range(i + 1):
            q = q - V[j].scale(V[j].inner(q))
        qn2 = float(np.asarray(q.norm()))
        if qn2 < GS_BREAKDOWN_TOL * max(qn, 1.0):
            # restart with A·(random): confined to range(A), so under the
            # bucket-padded matvec (dist/batch.py) the new direction stays
            # in the invariant unpadded subspace instead of acquiring O(1)
            # weight in the padded rows where the operator is zero
            info.restarts += 1
            q = matvec(BlockSparseTensor.random(
                x.indices, x.charge, jax.random.PRNGKey(seed + i), dtype=x.dtype
            ))
            for j in range(i + 1):
                q = q - V[j].scale(V[j].inner(q))
            qn2 = float(np.asarray(q.norm()))
            if qn2 < GS_BREAKDOWN_TOL * max(qn, 1.0):
                info.exhausted = True
                break  # subspace exhausted; accept the current Ritz pair
        q = q.scale(1.0 / qn2)
        V.append(q)
        AV.append(matvec(q))

    return lam, x.scale(1.0 / x.norm()), info
