"""Davidson eigensolver (paper Alg. 1).

Follows the paper's ITensor-derived implementation: no preconditioning,
modified Gram-Schmidt re-orthogonalization with randomization on breakdown,
small subspace (size 2 during production sweeps).  Operates directly on
block-sparse tensors; the matvec is the environment contraction of Fig. 1d.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor


def davidson(
    matvec: Callable[[BlockSparseTensor], BlockSparseTensor],
    x0: BlockSparseTensor,
    n_iter: int = 2,
    tol: float = 1e-10,
    seed: int = 0,
) -> Tuple[float, BlockSparseTensor]:
    """Return (smallest eigenvalue, eigenvector approximation)."""
    nrm = x0.norm()
    x = x0.scale(1.0 / nrm)
    V = [x]
    AV = [matvec(x)]
    M = np.zeros((n_iter + 1, n_iter + 1))
    lam = float(np.real(np.asarray(V[0].inner(AV[0]))))
    best = (lam, x)

    for i in range(n_iter):
        # subspace matrix M[j, i] = <v_j | A v_i>   (Hermitian)
        for j in range(i + 1):
            mij = float(np.real(np.asarray(V[j].inner(AV[i]))))
            M[j, i] = M[i, j] = mij
        evals, evecs = np.linalg.eigh(M[: i + 1, : i + 1])
        lam, s = float(evals[0]), evecs[:, 0]

        # Ritz vector and residual q = A x - lam x
        x = V[0].scale(s[0])
        q = AV[0].scale(s[0])
        for j in range(1, i + 1):
            x = x + V[j].scale(s[j])
            q = q + AV[j].scale(s[j])
        q = q - x.scale(lam)
        best = (lam, x)

        qn = float(np.asarray(q.norm()))
        if qn < tol or i == n_iter - 1:
            break

        # modified Gram-Schmidt vs all v_j, randomize on breakdown (paper)
        for j in range(i + 1):
            q = q - V[j].scale(V[j].inner(q))
        qn2 = float(np.asarray(q.norm()))
        if qn2 < 1e-12 * max(qn, 1.0):
            q = BlockSparseTensor.random(
                x.indices, x.charge, jax.random.PRNGKey(seed + i), dtype=x.dtype
            )
            for j in range(i + 1):
                q = q - V[j].scale(V[j].inner(q))
            qn2 = float(np.asarray(q.norm()))
        q = q.scale(1.0 / qn2)
        V.append(q)
        AV.append(matvec(q))

    lam, x = best
    return lam, x.scale(1.0 / x.norm())
