"""Matrix product states with U(1)^n block sparsity.

Site tensor convention: T_j has indices (l: IN, sigma: OUT, r: OUT) and
tensor charge 0; bond charges accumulate Q_{j+1} = Q_j - q_{sigma_j}, so the
final (dangling, dim-1) right bond carries -Q_total.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor, contract, svd_split, flip_flow
from ..tensor.qn import Charge, IN, Index, OUT, qadd, qneg, qzero
from .siteops import LocalSpace


class MPS:
    def __init__(self, tensors: List[BlockSparseTensor]):
        self.tensors = tensors

    @property
    def n_sites(self) -> int:
        return len(self.tensors)

    def bond_dims(self) -> List[int]:
        return [t.indices[2].dim for t in self.tensors[:-1]]

    def max_bond(self) -> int:
        dims = self.bond_dims()
        return max(dims) if dims else 1

    def total_blocks(self) -> int:
        return sum(t.num_blocks for t in self.tensors)

    def norm_sq(self):
        """<psi|psi> by transfer-matrix contraction."""
        env = None
        for t in self.tensors:
            bra = t.conj()
            if env is None:
                env = contract(bra, t, axes=((0, 1), (0, 1)))  # (r_bra, r_ket)
            else:
                tmp = contract(env, t, axes=((1,), (0,)))       # (r_bra, sigma, r)
                env = contract(bra, tmp, axes=((0, 1), (0, 1)))
        # env is (1,1)-ish block tensor; sum its entries
        acc = 0.0
        for b in env.blocks.values():
            acc = acc + jnp.sum(b)
        return jnp.real(acc)

    def copy(self) -> "MPS":
        return MPS([BlockSparseTensor(t.indices, dict(t.blocks), t.charge) for t in self.tensors])


def product_state_mps(
    space: LocalSpace, states: Sequence[int], dtype=jnp.float64
) -> MPS:
    """Bond-dimension-1 MPS for a product basis state (e.g. Neel)."""
    nq = len(space.state_charges[0])
    tensors = []
    q_left = qzero(nq)
    for s in states:
        q_right = tuple(a - b for a, b in zip(q_left, space.state_charges[s]))
        lix = Index(((q_left, 1),), IN, "l")
        rix = Index(((q_right, 1),), OUT, "r")
        block = jnp.ones((1, 1, 1), dtype)
        tensors.append(
            BlockSparseTensor([lix, space.index, rix], {(0, s, 0): block})
        )
        q_left = q_right
    return MPS(tensors)


def neel_states(space: LocalSpace, n: int) -> List[int]:
    """Alternating up/down (spins) or up-electron/down-electron (Hubbard
    half filling): a total-charge-zero / half-filled starting state."""
    if space.name == "spin_half":
        return [0 if i % 2 == 0 else 1 for i in range(n)]
    if space.name == "electron":
        return [1 if i % 2 == 0 else 2 for i in range(n)]
    raise ValueError(space.name)


def total_charge(space: LocalSpace, states: Sequence[int]) -> Charge:
    nq = len(space.state_charges[0])
    q = qzero(nq)
    for s in states:
        q = qadd(q, space.state_charges[s])
    return q


def right_canonicalize(mps: MPS, max_bond: int = 10**9, cutoff: float = 0.0) -> MPS:
    """Sweep right-to-left, SVD-splitting each bond; center lands at site 0."""
    tensors = list(mps.tensors)
    n = len(tensors)
    for j in range(n - 1, 0, -1):
        theta = contract(tensors[j - 1], tensors[j], axes=((2,), (0,)))
        U, V, _, _ = svd_split(theta, 2, max_bond=max_bond, cutoff=cutoff, absorb="left")
        U = flip_flow(U, 2)
        V = flip_flow(V, 0)
        tensors[j - 1], tensors[j] = U, V
    return MPS(tensors)
