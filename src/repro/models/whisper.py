"""Whisper-style encoder-decoder (whisper-tiny backbone, arXiv:2212.04356).

The conv1d+GELU audio frontend is a STUB per the assignment: ``enc_embeds``
arrive precomputed as [B, enc_seq, d_model] frame embeddings.  Both stacks use
pre-LayerNorm blocks with GELU MLPs and biased projections; sinusoidal
positions stand in for Whisper's learned decoder positions (structural
equivalence — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import causal_attention, cross_attention, decode_attention
from .common import Registry, dtype_of, gelu_mlp, layer_norm, sinusoidal_positions, sub


def _attn_p(reg, prefix, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads * cfg.resolved_head_dim
    for w, shape, axes in (
        ("wq", (d, h), ("embed", "heads")),
        ("wk", (d, h), ("embed", "heads")),
        ("wv", (d, h), ("embed", "heads")),
        ("wo", (h, d), ("heads", "embed")),
    ):
        reg.add(f"{prefix}/{w}", shape, axes, dtype=dtype)
    for b, n in (("bq", h), ("bv", h), ("bo", d)):
        reg.add(f"{prefix}/{b}", (n,), ("heads" if n == h else "embed",),
                zeros=True, dtype=dtype)


def _mlp_p(reg, prefix, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    reg.add(f"{prefix}/w1", (d, f), ("embed", "ff"), dtype=dtype)
    reg.add(f"{prefix}/b1", (f,), ("ff",), zeros=True, dtype=dtype)
    reg.add(f"{prefix}/w2", (f, d), ("ff", "embed"), dtype=dtype)
    reg.add(f"{prefix}/b2", (d,), ("embed",), zeros=True, dtype=dtype)


def _ln_p(reg, prefix, cfg, dtype):
    reg.add(f"{prefix}_g", (cfg.d_model,), ("embed",), zeros=True, dtype=dtype)
    reg.add(f"{prefix}_b", (cfg.d_model,), ("embed",), zeros=True, dtype=dtype)


def init_whisper(cfg, key) -> Tuple[Dict, Dict]:
    dtype = dtype_of(cfg)
    reg = Registry(key)
    d = cfg.d_model
    from .lm import padded_vocab

    reg.add("embed", (padded_vocab(cfg), d), ("vocab", "embed"), scale=0.02, dtype=dtype)

    def stack_layers(name, n, kinds):
        stacked: Dict[str, list] = {}
        axes = {}
        for _ in range(n):
            blk = Registry(reg.key())
            _ln_p(blk, "ln1", cfg, dtype)
            _attn_p(blk, "self", cfg, dtype)
            if "cross" in kinds:
                _ln_p(blk, "ln2", cfg, dtype)
                _attn_p(blk, "cross", cfg, dtype)
            _ln_p(blk, "ln3", cfg, dtype)
            _mlp_p(blk, "mlp", cfg, dtype)
            for k, v in blk.params.items():
                stacked.setdefault(k, []).append(v)
            axes = blk.axes
        for k, vs in stacked.items():
            reg.params[f"{name}/{k}"] = jnp.stack(vs)
            reg.axes[f"{name}/{k}"] = ("layers",) + axes[k]

    stack_layers("enc", cfg.n_enc_layers, ("self",))
    stack_layers("dec", cfg.n_layers, ("self", "cross"))
    _ln_p(reg, "enc_lnf", cfg, dtype)
    _ln_p(reg, "dec_lnf", cfg, dtype)
    return reg.params, reg.axes


def _proj_qkv(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (jnp.einsum("bsd,dh->bsh", x, p["wq"]) + p["bq"]).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, cfg.n_heads, hd)
    v = (jnp.einsum("bsd,dh->bsh", x, p["wv"]) + p["bv"]).reshape(b, s, cfg.n_heads, hd)
    return q, k, v


def _out(p, o, cfg):
    b, s = o.shape[:2]
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"]) + p["bo"]


def whisper_encode(cfg, params, enc_embeds):
    dtype = dtype_of(cfg)
    x = enc_embeds.astype(dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(dtype)

    def body(xc, lp):
        xa = layer_norm(xc, 1.0 + lp["ln1_g"], lp["ln1_b"])
        q, k, v = _proj_qkv(sub(lp, "self"), xa, cfg)
        xc = xc + _out(sub(lp, "self"), cross_attention(q, k, v), cfg)
        xm = layer_norm(xc, 1.0 + lp["ln3_g"], lp["ln3_b"])
        mp = sub(lp, "mlp")
        return xc + gelu_mlp(xm, mp["w1"], mp["b1"], mp["w2"], mp["b2"]), None

    x, _ = jax.lax.scan(body, x, sub(params, "enc"))
    return layer_norm(x, 1.0 + params["enc_lnf_g"], params["enc_lnf_b"])


def whisper_forward(cfg, params, enc_embeds, tokens):
    """Teacher-forced decoder over the full token sequence."""
    enc = whisper_encode(cfg, params, enc_embeds)
    dtype = dtype_of(cfg)
    x = params["embed"][tokens]
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(dtype)

    def body(xc, lp):
        xa = layer_norm(xc, 1.0 + lp["ln1_g"], lp["ln1_b"])
        q, k, v = _proj_qkv(sub(lp, "self"), xa, cfg)
        xc = xc + _out(sub(lp, "self"), causal_attention(q, k, v), cfg)
        xa = layer_norm(xc, 1.0 + lp["ln2_g"], lp["ln2_b"])
        cp = sub(lp, "cross")
        q2, _, _ = _proj_qkv(cp, xa, cfg)
        ek = jnp.einsum("bsd,dh->bsh", enc, cp["wk"]).reshape(
            enc.shape[0], enc.shape[1], cfg.n_heads, cfg.resolved_head_dim)
        ev = (jnp.einsum("bsd,dh->bsh", enc, cp["wv"]) + cp["bv"]).reshape(
            enc.shape[0], enc.shape[1], cfg.n_heads, cfg.resolved_head_dim)
        xc = xc + _out(cp, cross_attention(q2, ek, ev), cfg)
        xm = layer_norm(xc, 1.0 + lp["ln3_g"], lp["ln3_b"])
        mp = sub(lp, "mlp")
        return xc + gelu_mlp(xm, mp["w1"], mp["b1"], mp["w2"], mp["b2"]), None

    x, _ = jax.lax.scan(body, x, sub(params, "dec"))
    x = layer_norm(x, 1.0 + params["dec_lnf_g"], params["dec_lnf_b"])
    return jnp.einsum("bsd,dv->bsv", x, params["embed"].T)


def whisper_loss(cfg, params, batch):
    from .common import cross_entropy_loss

    logits = whisper_forward(cfg, params, batch["enc_embeds"], batch["tokens"])
    logits = logits[..., : cfg.vocab_size]
    labels = batch["labels"]
    return cross_entropy_loss(logits, jnp.maximum(labels, 0), mask=labels >= 0)


# ------------------------------------------------------------------ decode
def init_whisper_cache(cfg, batch: int, cache_len: int) -> Dict:
    dtype = dtype_of(cfg)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    h = cfg.n_heads
    return {
        "self_k": jnp.zeros((L, batch, cache_len, h, hd), dtype),
        "self_v": jnp.zeros((L, batch, cache_len, h, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.enc_seq_len, h, hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.enc_seq_len, h, hd), dtype),
    }


def decode_cache_axes(cfg) -> Dict:
    a = ("layers", "cache_batch", "cache_seq", "heads", "head_dim")
    c = ("layers", "cache_batch", "frames", "heads", "head_dim")
    return {"self_k": a, "self_v": a, "cross_k": c, "cross_v": c}


def whisper_prime_cache(cfg, params, cache, enc_embeds):
    """Precompute per-layer cross K/V from the encoder output."""
    enc = whisper_encode(cfg, params, enc_embeds)

    def body(_, lp):
        cp = sub(lp, "cross")
        hd = cfg.resolved_head_dim
        ek = jnp.einsum("bsd,dh->bsh", enc, cp["wk"]).reshape(
            enc.shape[0], enc.shape[1], cfg.n_heads, hd)
        ev = (jnp.einsum("bsd,dh->bsh", enc, cp["wv"]) + cp["bv"]).reshape(
            enc.shape[0], enc.shape[1], cfg.n_heads, hd)
        return None, (ek, ev)

    _, (cks, cvs) = jax.lax.scan(body, None, sub(params, "dec"))
    return dict(cache, cross_k=cks, cross_v=cvs)


def whisper_decode_step(cfg, params, cache, token, pos):
    dtype = dtype_of(cfg)
    x1 = params["embed"][token][:, None, :]
    # per-step sinusoidal position for the current pos
    half = cfg.d_model // 2
    dim = jnp.arange(half, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / cfg.d_model)
    posvec = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
    x1 = x1 + posvec.astype(dtype)

    def body(xc, inp):
        lp, sk, sv, ck, cv = inp
        hd = cfg.resolved_head_dim
        b = xc.shape[0]
        xa = layer_norm(xc, 1.0 + lp["ln1_g"], lp["ln1_b"])
        q, k, v = _proj_qkv(sub(lp, "self"), xa, cfg)
        z = jnp.zeros((), jnp.int32)
        sk = jax.lax.dynamic_update_slice(sk, k, (z, pos.astype(jnp.int32), z, z))
        sv = jax.lax.dynamic_update_slice(sv, v, (z, pos.astype(jnp.int32), z, z))
        xc = xc + _out(sub(lp, "self"), decode_attention(q, sk, sv, pos), cfg)
        xa = layer_norm(xc, 1.0 + lp["ln2_g"], lp["ln2_b"])
        cp = sub(lp, "cross")
        q2, _, _ = _proj_qkv(cp, xa, cfg)
        xc = xc + _out(cp, cross_attention(q2, ck, cv), cfg)
        xm = layer_norm(xc, 1.0 + lp["ln3_g"], lp["ln3_b"])
        mp = sub(lp, "mlp")
        xc = xc + gelu_mlp(xm, mp["w1"], mp["b1"], mp["w2"], mp["b2"])
        return xc, (sk, sv)

    x1, (nsk, nsv) = jax.lax.scan(
        body, x1,
        (sub(params, "dec"), cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    x1 = layer_norm(x1, 1.0 + params["dec_lnf_g"], params["dec_lnf_b"])
    logits = jnp.einsum("bsd,dv->bsv", x1, params["embed"].T)[:, 0]
    return logits, dict(cache, self_k=nsk, self_v=nsv)
