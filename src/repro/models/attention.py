"""Attention blocks: causal GQA (optionally RoPE), sliding-window local
attention (RecurrentGemma), cross-attention (Whisper), and one-token decode
against a KV cache.

Training/prefill paths broadcast KV heads up to the query head count and
apply TP sharding hints on the head axis, so the [*, H, S, S] logits tensor
shards over "model" (the KV broadcast costs O(B*S*H*D) bytes — orders of
magnitude below the logits it lets us shard).  Decode keeps the cache at
n_kv_heads and uses the grouped form (logits are tiny at S_q=1).

The jnp paths here are the canonical model definition (and what the dry-run
lowers); ``repro.kernels.flash_attention`` provides the Pallas TPU kernel for
the prefill hot-spot, validated against these in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import batch_axes, shard_hint

NEG_INF = -2.0**30

FLASH_THRESHOLD = 8192  # switch to query-chunked attention above this length
FLASH_CHUNK = 512


def _expand_kv(k, n_heads: int):
    """[B,S,Hkv,D] -> [B,S,H,D] broadcast, sharded on the head axis."""
    b, s, hkv, d = k.shape
    if hkv != n_heads:
        k = jnp.broadcast_to(
            k[:, :, :, None, :], (b, s, hkv, n_heads // hkv, d)
        ).reshape(b, s, n_heads, d)
    return shard_hint(k, batch_axes(), None, "model", None)


def causal_attention(q, k, v, *, local_window: int = 0):
    """q: [B,S,H,D]; k,v: [B,S,Hkv,D]. Returns [B,S,H,D].

    With ``local_window`` > 0 the mask is banded (sliding window); for long
    sequences the computation is block-local: O(S*W) instead of O(S^2).
    Long full-attention sequences take the query-chunked path so the [S,S]
    logits matrix is never materialized (peak extra memory O(chunk*S))."""
    if local_window and q.shape[1] > 2 * local_window:
        return _windowed_attention(q, k, v, local_window)
    if not local_window and q.shape[1] >= FLASH_THRESHOLD:
        return _chunked_causal_attention(q, k, v, FLASH_CHUNK)
    b, s, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q = shard_hint(q, batch_axes(), None, "model", None)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = shard_hint(logits, batch_axes(), "model", "model", None)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if local_window:
        mask = jnp.logical_and(mask, kpos > qpos - local_window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _chunked_causal_attention(q, k, v, chunk: int):
    """lax.scan over query chunks; each chunk attends to the full key range
    with a causal mask and a single softmax (the whole key axis is resident
    per chunk, so no online rescaling is needed).  Peak transient memory is
    [B, H, chunk, S] instead of [B, H, S, S]."""
    b, s, h, d = q.shape
    assert s % chunk == 0, (s, chunk)
    nq = s // chunk
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q = shard_hint(q, batch_axes(), None, "model", None)
    qc = jnp.moveaxis(q.reshape(b, nq, chunk, h, d), 1, 0)  # [nq,B,c,h,d]
    scale = 1.0 / np.sqrt(d)
    kpos = jnp.arange(s)

    def body(_, inp):
        qi, idx = inp
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi, k).astype(jnp.float32) * scale
        logits = shard_hint(logits, batch_axes(), "model", None, "model")
        qpos = idx * chunk + jnp.arange(chunk)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return None, o

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)


def _windowed_attention(q, k, v, window: int):
    """Block-local sliding-window attention: each query block of size W
    attends to its own and the previous key block => O(S*2W*D)."""
    b, s, h, d = q.shape
    w = window
    nb = (s + w - 1) // w
    pad = nb * w - s
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q = shard_hint(q, batch_axes(), None, "model", None)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, w, h, d)
    kb = k.reshape(b, nb, w, h, d)
    vb = v.reshape(b, nb, w, h, d)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # [B,nb,2w,h,d]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2).astype(jnp.float32) * scale
    logits = shard_hint(logits, batch_axes(), None, "model", "model", None)
    qpos = jnp.arange(w)[:, None] + w  # position on the 2w key axis
    kpos = jnp.arange(2 * w)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    first_block = jnp.arange(nb)[:, None, None] == 0
    valid = jnp.logical_and(mask[None], ~(first_block & (kpos[None] < w)))
    logits = jnp.where(valid[:, None][None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, v2).reshape(b, nb * w, h, d)
    return out[:, :s]


def cross_attention(q, k, v):
    """q: [B,Sq,H,D]; k,v: [B,Sk,Hkv,D]; full (non-causal) attention."""
    b, sq, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(d)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def decode_attention(q1, k_cache, v_cache, pos, *, local_window: int = 0):
    """One-token decode: q1 [B,1,H,D], caches [B,S,Hkv,D]; attends to cache
    positions <= pos (banded if local). pos: scalar int32.  Grouped form —
    the cache stays at n_kv_heads, logits are [B,Hkv,rep,1,S]."""
    b, s, hkv, d = k_cache.shape
    h = q1.shape[2]
    qg = q1.reshape(b, 1, hkv, h // hkv, d)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache).astype(jnp.float32)
    logits = logits / np.sqrt(d)
    kpos = jnp.arange(s)
    mask = kpos <= pos
    if local_window:
        mask = jnp.logical_and(mask, kpos > pos - local_window)
    logits = jnp.where(mask[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q1.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v_cache)
    return out.reshape(b, 1, h, d)
