"""Shared model building blocks, functional-JAX style.

Parameters live in FLAT dicts keyed by slash paths ("layers/attn/wq"); a
parallel dict maps each path to a tuple of *logical axis names* which
``launch/mesh.py`` resolves to mesh axes (TP over "model", FSDP over "data").
Layer-stacked parameters carry a leading "layers" axis and run under
``jax.lax.scan`` so the HLO stays one-layer-sized for 80-layer models.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]
Axes = Dict[str, Tuple[str, ...]]

# mesh axes carrying the batch dim of activations. The default (pod, data)
# leaves "model" for TP; the pure-FSDP hillclimb (EXPERIMENTS.md §Perf) sets
# this to ("pod", "data", "model") so batch shards over the whole mesh and
# no tensor parallelism occurs.
BATCH_AXES = ("pod", "data")


def batch_axes():
    return BATCH_AXES


def dtype_of(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


class Registry:
    """Collects params + logical axes during init."""

    def __init__(self, key: jax.Array):
        self.params: Params = {}
        self.axes: Axes = {}
        self._key = key

    def key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, path: str, shape, axes, scale=None, dtype=jnp.float32, zeros=False):
        assert len(shape) == len(axes), (path, shape, axes)
        if zeros:
            v = jnp.zeros(shape, dtype)
        else:
            scale = scale if scale is not None else 1.0 / np.sqrt(shape[-2] if len(shape) > 1 else shape[-1])
            v = (jax.random.normal(self.key(), shape, dtype) * float(scale)).astype(dtype)
        self.params[path] = v
        self.axes[path] = tuple(axes)
        return v


def sub(params: Params, prefix: str) -> Params:
    """View of a flat dict under a path prefix (strips the prefix)."""
    p = prefix + "/"
    return {k[len(p):]: v for k, v in params.items() if k.startswith(p)}


def shard_hint(x, *spec):
    """with_sharding_constraint that degrades gracefully: applied only when
    a mesh is in context (jax.sharding.set_mesh), and each named axis is
    dropped unless it exists in the mesh and divides the dim size.  Keeps
    model code mesh-agnostic — smoke tests see a no-op."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or not am.axis_names:
        return x
    sizes = dict(zip(am.axis_names, am.axis_sizes))
    resolved = []
    used: set = set()
    for dim, names in zip(x.shape, spec):
        if names is None:
            resolved.append(None)
            continue
        tup = names if isinstance(names, tuple) else (names,)
        # keep only axes present in the mesh (e.g. "pod" on single-pod runs)
        # and not already used — the same axis may be listed on several dims
        # as a fallback chain (first divisible dim wins);
        # then drop leading axes until the product divides the dim
        tup = tuple(n for n in tup if n in sizes and n not in used)
        while tup and dim % int(np.prod([sizes[n] for n in tup])) != 0:
            tup = tup[1:]
        used.update(tup)
        if not tup:
            resolved.append(None)
        elif len(tup) == 1:
            resolved.append(tup[0])
        else:
            resolved.append(tup)
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*resolved))


def rms_norm(x, gamma, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embeddings; x: [..., S, H, Dh], positions: [..., S]."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d_model)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32
    )


def act_hint(x):
    """TP layout for an up-projected activation [..., S, F]: F over "model".
    Pins the Megatron column-parallel layout so SPMD never falls back to
    gathering the full weight."""
    return shard_hint(x, *([batch_axes()] + [None] * (x.ndim - 2) + ["model"]))


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(act_hint(jnp.einsum("...d,df->...f", x, w_gate)))
    u = act_hint(jnp.einsum("...d,df->...f", x, w_up))
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(act_hint(jnp.einsum("...d,df->...f", x, w1) + b1))
    return jnp.einsum("...f,fd->...d", h, w2) + b2


def cross_entropy_loss(logits, labels, mask=None, z_loss: float = 0.0):
    """Mean token CE in float32, optional masking and z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(loss)
    mask = mask.astype(jnp.float32)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
