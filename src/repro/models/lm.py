"""Decoder-only LM assembly for all assigned non-enc-dec architectures:
dense GQA (llama3/qwen/granite), MoE (qwen2-moe/moonshot), RWKV6 (ssm),
RG-LRU hybrid (recurrentgemma), and VLM (pixtral, stubbed patch frontend).

Layers run under jax.lax.scan over stacked parameters so the traced HLO is
one layer deep regardless of depth (80-layer qwen110b compiles in the same
program size as 2 layers).  Heterogeneous layer patterns (recurrentgemma's
rglru/rglru/attn) scan over whole pattern blocks, with any remainder layers
unrolled.

Params are flat dicts path -> array; ``init_lm`` also returns path -> logical
axes resolved to mesh shardings by launch/sharding.py.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import rglru as rg
from . import rwkv6 as rk
from .attention import causal_attention, decode_attention
from .common import (
    Registry,
    batch_axes,
    cross_entropy_loss,
    dtype_of,
    layer_norm,
    rms_norm,
    rope,
    shard_hint,
    sub,
    swiglu,
)
from .moe import moe_ffn

VOCAB_PAD = 512


def padded_vocab(cfg) -> int:
    return ((cfg.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# --------------------------------------------------------------------- init
def _ffn_params(reg: Registry, prefix: str, cfg, dtype):
    d = cfg.d_model
    if cfg.n_experts:
        reg.add(f"{prefix}/router", (d, cfg.n_experts), ("embed", "expert_in"), dtype=dtype)
        reg.add(f"{prefix}/w_gate", (cfg.n_experts, d, cfg.moe_d_ff),
                ("expert", "embed", "expert_ff"), dtype=dtype)
        reg.add(f"{prefix}/w_up", (cfg.n_experts, d, cfg.moe_d_ff),
                ("expert", "embed", "expert_ff"), dtype=dtype)
        reg.add(f"{prefix}/w_down", (cfg.n_experts, cfg.moe_d_ff, d),
                ("expert", "expert_ff", "embed"), dtype=dtype)
        if cfg.n_shared_experts:
            reg.add(f"{prefix}/sh_gate", (d, cfg.d_ff), ("embed", "ff"), dtype=dtype)
            reg.add(f"{prefix}/sh_up", (d, cfg.d_ff), ("embed", "ff"), dtype=dtype)
            reg.add(f"{prefix}/sh_down", (cfg.d_ff, d), ("ff", "embed"), dtype=dtype)
    else:
        reg.add(f"{prefix}/w_gate", (d, cfg.d_ff), ("embed", "ff"), dtype=dtype)
        reg.add(f"{prefix}/w_up", (d, cfg.d_ff), ("embed", "ff"), dtype=dtype)
        reg.add(f"{prefix}/w_down", (cfg.d_ff, d), ("ff", "embed"), dtype=dtype)


def _attn_params(reg: Registry, prefix: str, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    reg.add(f"{prefix}/wq", (d, cfg.n_heads * hd), ("embed", "heads"), dtype=dtype)
    reg.add(f"{prefix}/wk", (d, cfg.n_kv_heads * hd), ("embed", "kv_heads"), dtype=dtype)
    reg.add(f"{prefix}/wv", (d, cfg.n_kv_heads * hd), ("embed", "kv_heads"), dtype=dtype)
    reg.add(f"{prefix}/wo", (cfg.n_heads * hd, d), ("heads", "embed"), dtype=dtype)
    if cfg.qkv_bias:
        reg.add(f"{prefix}/bq", (cfg.n_heads * hd,), ("heads",), zeros=True, dtype=dtype)
        reg.add(f"{prefix}/bk", (cfg.n_kv_heads * hd,), ("kv_heads",), zeros=True, dtype=dtype)
        reg.add(f"{prefix}/bv", (cfg.n_kv_heads * hd,), ("kv_heads",), zeros=True, dtype=dtype)


def _layer_params(reg: Registry, prefix: str, kind: str, cfg, dtype):
    d = cfg.d_model
    if kind == "attn":
        reg.add(f"{prefix}/ln1", (d,), ("embed",), zeros=True, dtype=dtype)
        _attn_params(reg, f"{prefix}/attn", cfg, dtype)
        reg.add(f"{prefix}/ln2", (d,), ("embed",), zeros=True, dtype=dtype)
        _ffn_params(reg, f"{prefix}/ffn", cfg, dtype)
    elif kind == "rglru":
        reg.add(f"{prefix}/ln1", (d,), ("embed",), zeros=True, dtype=dtype)
        rg.rglru_params(reg, f"{prefix}/rec", d, cfg.d_rnn, cfg.conv_width, dtype)
        reg.add(f"{prefix}/ln2", (d,), ("embed",), zeros=True, dtype=dtype)
        _ffn_params(reg, f"{prefix}/ffn", cfg, dtype)
    elif kind == "rwkv":
        for ln in ("ln1_g", "ln1_b", "ln2_g", "ln2_b"):
            reg.add(f"{prefix}/{ln}", (d,), ("embed",), zeros=True, dtype=dtype)
        rk.time_mix_params(reg, f"{prefix}/tm", d, cfg.n_heads,
                           cfg.rwkv_head_dim, dtype=dtype)
        rk.channel_mix_params(reg, f"{prefix}/cm", d, cfg.d_ff, dtype=dtype)
    else:
        raise ValueError(kind)


def _stack_pattern(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """Returns (pattern kinds, n_scanned_blocks, remainder kinds)."""
    if cfg.family == "ssm":
        pat = ("rwkv",)
    elif cfg.block_pattern:
        pat = cfg.block_pattern
    else:
        pat = ("attn",)
    n_full = cfg.n_layers // len(pat)
    rem = tuple(pat[i] for i in range(cfg.n_layers - n_full * len(pat)))
    return pat, n_full, rem


def init_lm(cfg, key) -> Tuple[Dict, Dict]:
    dtype = dtype_of(cfg)
    reg = Registry(key)
    d, v = cfg.d_model, padded_vocab(cfg)
    reg.add("embed", (v, d), ("vocab", "embed"), scale=0.02, dtype=dtype)
    if cfg.family == "ssm":
        reg.add("ln0_g", (d,), ("embed",), zeros=True, dtype=dtype)
        reg.add("ln0_b", (d,), ("embed",), zeros=True, dtype=dtype)
    if cfg.family == "vlm":
        reg.add("patch_proj", (d, d), ("embed", "embed2"), dtype=dtype)
    pat, n_full, rem = _stack_pattern(cfg)

    # scanned pattern blocks: init one block at a time, then stack
    stacked: Dict[str, list] = {}
    for _ in range(n_full):
        blk = Registry(reg.key())
        for pi, kind in enumerate(pat):
            _layer_params(blk, f"L{pi}", kind, cfg, dtype)
        for k, vv in blk.params.items():
            stacked.setdefault(k, []).append(vv)
        block_axes = blk.axes
    for k, vs in stacked.items():
        reg.params[f"blocks/{k}"] = jnp.stack(vs)
        reg.axes[f"blocks/{k}"] = ("layers",) + block_axes[k]
    for ri, kind in enumerate(rem):
        _layer_params(reg, f"rem{ri}", kind, cfg, dtype)

    reg.add("ln_f", (d,), ("embed",), zeros=True, dtype=dtype)
    if not cfg.tie_embeddings:
        reg.add("lm_head", (d, v), ("embed", "vocab"), scale=0.02, dtype=dtype)
    return reg.params, reg.axes


# ------------------------------------------------------------------- apply
# ZeRO-3 weight gathering: FSDP keeps weights sharded over "data" at rest;
# before use we constrain each weight to (replicated-over-data x TP-sharded),
# which makes XLA insert the per-layer weight all-gather (cheap, O(params))
# instead of falling back to per-token activation all-reduces (O(B*S*D)).
# MoE expert weights are excluded — they stay fully sharded (EP).
_GATHER_SPECS = {
    "attn/wq": (None, "model"), "attn/wk": (None, "model"),
    "attn/wv": (None, "model"), "attn/wo": ("model", None),
    "ffn/w_gate": (None, "model"), "ffn/w_up": (None, "model"),
    "ffn/w_down": ("model", None),
    # MoE experts: EP over "model" when E divides it (moonshot), else the
    # expert-ff width shards (qwen2's 60 experts) — fallback via hint dedup
    "ffn/router": (None, None),
    ("ffn/w_gate", 3): ("model", None, "model"),
    ("ffn/w_up", 3): ("model", None, "model"),
    ("ffn/w_down", 3): ("model", "model", None),
    "ffn/sh_gate": (None, "model"), "ffn/sh_up": (None, "model"),
    "ffn/sh_down": ("model", None),
    "rec/w_x": (None, "model"), "rec/w_gate": (None, "model"),
    "rec/w_out": ("model", None),
    "rec/w_a": ("model", None), "rec/w_i": ("model", None),
    "tm/w_r": (None, "model"), "tm/w_k": (None, "model"),
    "tm/w_v": (None, "model"), "tm/w_g": (None, "model"),
    "tm/w_o": (None, "model"),
    "cm/w_k": (None, "model"), "cm/w_v": ("model", None),
    "cm/w_r": (None, "model"),
}


def _gather_weights(lp: Dict) -> Dict:
    out = dict(lp)
    for k, v in lp.items():
        spec = _GATHER_SPECS.get((k, v.ndim), _GATHER_SPECS.get(k))
        if spec is not None and len(spec) == v.ndim:
            out[k] = shard_hint(v, *spec)
    return out


def _ffn_apply(lp: Dict, x, cfg, *, decode: bool = False):
    if cfg.n_experts:
        # decode batches are small: use dropless capacity (cap == T worst
        # case) — a served token must never be dropped by the router
        cap = float(cfg.n_experts) / cfg.top_k if decode else cfg.capacity_factor
        y = moe_ffn(
            x, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"],
            top_k=cfg.top_k, capacity_factor=cap,
        )
        if cfg.n_shared_experts:
            y = y + swiglu(x, lp["sh_gate"], lp["sh_up"], lp["sh_down"])
        return y
    return swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def _attn_apply(lp: Dict, x, cfg, positions, *, local: bool):
    from .common import act_hint

    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = act_hint(jnp.einsum("bsd,dh->bsh", x, lp["wq"]))
    k = act_hint(jnp.einsum("bsd,dh->bsh", x, lp["wk"]))
    v = act_hint(jnp.einsum("bsd,dh->bsh", x, lp["wv"]))
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    window = cfg.local_window if local else 0
    o = causal_attention(q, k, v, local_window=window)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, cfg.n_heads * hd), lp["wo"])


def _apply_layer(kind: str, lp: Dict, x, cfg, positions):
    lp = _gather_weights(lp)
    if kind == "attn":
        a = _attn_apply(sub(lp, "attn"), rms_norm(x, lp["ln1"], cfg.norm_eps),
                        cfg, positions, local=bool(cfg.local_window))
        x = x + a
        f = _ffn_apply(sub(lp, "ffn"), rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + f
    if kind == "rglru":
        r, _ = rg.rglru_block(sub(lp, "rec"), rms_norm(x, lp["ln1"], cfg.norm_eps))
        x = x + r
        f = _ffn_apply(sub(lp, "ffn"), rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
        return x + f
    if kind == "rwkv":
        t, _ = rk.time_mix(sub(lp, "tm"), layer_norm(x, 1.0 + lp["ln1_g"], lp["ln1_b"]),
                           cfg.n_heads, cfg.rwkv_head_dim)
        x = x + t
        c, _ = rk.channel_mix(sub(lp, "cm"),
                              layer_norm(x, 1.0 + lp["ln2_g"], lp["ln2_b"]))
        return x + c
    raise ValueError(kind)


def lm_forward(cfg, params: Dict, tokens, patch_embeds=None):
    """tokens: [B,S_text] int32 -> logits [B,S_total,V_padded]."""
    dtype = dtype_of(cfg)
    x = params["embed"][tokens]
    if cfg.family == "vlm":
        assert patch_embeds is not None
        pe = jnp.einsum("bpd,de->bpe", patch_embeds.astype(dtype), params["patch_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    if cfg.family == "ssm":
        x = layer_norm(x, 1.0 + params["ln0_g"], params["ln0_b"])
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    pat, n_full, rem = _stack_pattern(cfg)

    # activation layout between layers: batch over (pod,data), seq over model
    # (sequence parallelism — keeps the 80-layer scan carry 256-way sharded)
    def hint(xc):
        return shard_hint(xc, batch_axes(), "model", None)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def block_body_remat(xc, blk_params):
        for pi, kind in enumerate(pat):
            xc = _apply_layer(kind, sub(blk_params, f"L{pi}"), xc, cfg, positions)
        return hint(xc)

    def block_body(xc, blk_params):
        return block_body_remat(xc, blk_params), None

    x = hint(x)
    if n_full:
        x, _ = jax.lax.scan(block_body, x, sub(params, "blocks"))
    for ri, kind in enumerate(rem):
        x = _apply_layer(kind, sub(params, f"rem{ri}"), x, cfg, positions)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def lm_loss(cfg, params: Dict, batch: Dict):
    """batch: tokens [B,S], labels [B,S] (-1 = masked), optional patch_embeds."""
    logits = lm_forward(cfg, params, batch["tokens"],
                        patch_embeds=batch.get("patch_embeds"))
    if cfg.family == "vlm":
        logits = logits[:, batch["patch_embeds"].shape[1]:]
    logits = logits[..., : cfg.vocab_size]
    labels = batch["labels"]
    return cross_entropy_loss(logits, jnp.maximum(labels, 0), mask=labels >= 0)


# ------------------------------------------------------------------ decode
def init_decode_cache(cfg, batch: int, cache_len: int) -> Dict:
    """Flat dict of stacked per-layer decode state ShapeDtypeStructs/arrays."""
    dtype = dtype_of(cfg)
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    d = cfg.d_model
    pat, n_full, rem = _stack_pattern(cfg)

    def kind_cache(kind: str, prefix: str, stack: Optional[int]):
        def mk(shape, dt):
            shape = (stack,) + shape if stack else shape
            return jnp.zeros(shape, dt)

        out = {}
        if kind == "attn":
            sl = min(cache_len, cfg.local_window) if cfg.local_window else cache_len
            cdt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
            out[f"{prefix}/k"] = mk((batch, sl, hkv, hd), cdt)
            out[f"{prefix}/v"] = mk((batch, sl, hkv, hd), cdt)
            if cfg.kv_cache_dtype == "int8":
                # per-(token, head) quantization scales
                out[f"{prefix}/k_scale"] = mk((batch, sl, hkv), jnp.float32)
                out[f"{prefix}/v_scale"] = mk((batch, sl, hkv), jnp.float32)
        elif kind == "rglru":
            out[f"{prefix}/h"] = mk((batch, cfg.d_rnn), jnp.float32)
            out[f"{prefix}/conv"] = mk((batch, cfg.conv_width - 1, cfg.d_rnn), dtype)
        elif kind == "rwkv":
            out[f"{prefix}/s"] = mk((batch, cfg.n_heads, cfg.rwkv_head_dim,
                                     cfg.rwkv_head_dim), jnp.float32)
            out[f"{prefix}/tm_last"] = mk((batch, d), dtype)
            out[f"{prefix}/cm_last"] = mk((batch, d), dtype)
        return out

    cache: Dict = {}
    for pi, kind in enumerate(pat):
        cache.update(kind_cache(kind, f"blocks/L{pi}", n_full if n_full else None))
    for ri, kind in enumerate(rem):
        cache.update(kind_cache(kind, f"rem{ri}", None))
    return cache


def decode_cache_axes(cfg) -> Dict:
    """Logical axes for every decode-cache entry (mirrors init_decode_cache)."""
    pat, n_full, rem = _stack_pattern(cfg)

    def kind_axes(kind: str, prefix: str, stacked: bool):
        lead = ("layers",) if stacked else ()
        if kind == "attn":
            a = lead + ("cache_batch", "cache_seq", "kv_heads", "head_dim")
            out = {f"{prefix}/k": a, f"{prefix}/v": a}
            if cfg.kv_cache_dtype == "int8":
                s = lead + ("cache_batch", "cache_seq", "kv_heads")
                out[f"{prefix}/k_scale"] = s
                out[f"{prefix}/v_scale"] = s
            return out
        if kind == "rglru":
            return {
                f"{prefix}/h": lead + ("cache_batch", "rnn"),
                f"{prefix}/conv": lead + ("cache_batch", "conv", "rnn"),
            }
        if kind == "rwkv":
            return {
                f"{prefix}/s": lead + ("cache_batch", "heads", "head_dim", "head_dim"),
                f"{prefix}/tm_last": lead + ("cache_batch", "hidden"),
                f"{prefix}/cm_last": lead + ("cache_batch", "hidden"),
            }
        raise ValueError(kind)

    axes: Dict = {}
    for pi, kind in enumerate(pat):
        axes.update(kind_axes(kind, f"blocks/L{pi}", bool(n_full)))
    for ri, kind in enumerate(rem):
        axes.update(kind_axes(kind, f"rem{ri}", False))
    return axes


def _decode_layer(kind: str, lp: Dict, lc: Dict, x1, cfg, pos):
    """One-token layer step. x1 [B,1,D]; returns (x1, new layer cache)."""
    lp = _gather_weights(lp)
    hd = cfg.resolved_head_dim
    b = x1.shape[0]
    new = {}
    if kind == "attn":
        xa = rms_norm(x1, lp["ln1"], cfg.norm_eps)
        ap = sub(lp, "attn")
        q = jnp.einsum("bsd,dh->bsh", xa, ap["wq"])
        k = jnp.einsum("bsd,dh->bsh", xa, ap["wk"])
        v = jnp.einsum("bsd,dh->bsh", xa, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        q = q.reshape(b, 1, cfg.n_heads, hd)
        k = k.reshape(b, 1, cfg.n_kv_heads, hd)
        v = v.reshape(b, 1, cfg.n_kv_heads, hd)
        posb = jnp.broadcast_to(pos[None], (b, 1)) if pos.ndim == 0 else pos[:, None]
        q = rope(q, posb, cfg.rope_theta)
        k = rope(k, posb, cfg.rope_theta)
        sl = lc["k"].shape[1]
        slot = (pos % sl if cfg.local_window else pos).astype(jnp.int32)
        z = jnp.zeros((), jnp.int32)
        if cfg.kv_cache_dtype == "int8":
            # absmax-per-(token, head) quantization on write; the cache READ
            # (the decode roofline floor) moves half the bytes of bf16
            def quant(t):
                sc = jnp.maximum(jnp.max(jnp.abs(t), axis=-1), 1e-8) / 127.0
                qt = jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
                return qt.astype(jnp.int8), sc.astype(jnp.float32)

            kq, ks = quant(k)
            vq, vs = quant(v)
            kc = jax.lax.dynamic_update_slice(lc["k"], kq, (z, slot, z, z))
            vc = jax.lax.dynamic_update_slice(lc["v"], vq, (z, slot, z, z))
            ksc = jax.lax.dynamic_update_slice(lc["k_scale"], ks, (z, slot, z))
            vsc = jax.lax.dynamic_update_slice(lc["v_scale"], vs, (z, slot, z))
            kf = kc.astype(k.dtype) * ksc[..., None].astype(k.dtype)
            vf = vc.astype(v.dtype) * vsc[..., None].astype(v.dtype)
            new["k_scale"], new["v_scale"] = ksc, vsc
        else:
            kc = jax.lax.dynamic_update_slice(lc["k"], k, (z, slot, z, z))
            vc = jax.lax.dynamic_update_slice(lc["v"], v, (z, slot, z, z))
            kf, vf = kc, vc
        # ring cache: every slot is within the window once full; early slots
        # are masked by index<=pos (ring) or kpos<=pos (linear)
        eff_pos = jnp.minimum(pos, sl - 1) if cfg.local_window else pos
        o = decode_attention(q, kf, vf, eff_pos)
        a = jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, cfg.n_heads * hd), ap["wo"])
        x1 = x1 + a
        f = _ffn_apply(sub(lp, "ffn"), rms_norm(x1, lp["ln2"], cfg.norm_eps), cfg,
                       decode=True)
        x1 = x1 + f
        new["k"], new["v"] = kc, vc
    elif kind == "rglru":
        xr = rms_norm(x1, lp["ln1"], cfg.norm_eps)
        r, (h, conv) = rg.rglru_decode(sub(lp, "rec"), xr, lc["h"], lc["conv"])
        x1 = x1 + r
        f = _ffn_apply(sub(lp, "ffn"), rms_norm(x1, lp["ln2"], cfg.norm_eps), cfg,
                       decode=True)
        x1 = x1 + f
        new["h"], new["conv"] = h, conv
    elif kind == "rwkv":
        xt = layer_norm(x1, 1.0 + lp["ln1_g"], lp["ln1_b"])
        t, (s_new, tml) = rk.time_mix_decode(
            sub(lp, "tm"), xt, lc["s"], lc["tm_last"], cfg.n_heads, cfg.rwkv_head_dim
        )
        x1 = x1 + t
        xc = layer_norm(x1, 1.0 + lp["ln2_g"], lp["ln2_b"])
        c, cml = rk.channel_mix_decode(sub(lp, "cm"), xc, lc["cm_last"])
        x1 = x1 + c
        new["s"], new["tm_last"], new["cm_last"] = s_new, tml, cml.astype(lc["cm_last"].dtype)
    return x1, new


def lm_decode_step(cfg, params: Dict, cache: Dict, token, pos):
    """token [B] int32, pos scalar int32 -> (logits [B,V], new cache)."""
    dtype = dtype_of(cfg)
    x1 = params["embed"][token][:, None, :]
    if cfg.family == "ssm":
        x1 = layer_norm(x1, 1.0 + params["ln0_g"], params["ln0_b"])
    pat, n_full, rem = _stack_pattern(cfg)

    new_cache: Dict = {}
    if n_full:
        stacked_p = sub(params, "blocks")
        stacked_c = {k: v for k, v in cache.items() if k.startswith("blocks/")}
        stacked_c = {k[len("blocks/"):]: v for k, v in stacked_c.items()}

        def body(xc, inp):
            lp, lc = inp
            outs = {}
            for pi, kind in enumerate(pat):
                xc, nc = _decode_layer(kind, sub(lp, f"L{pi}"), sub(lc, f"L{pi}"),
                                       xc, cfg, pos)
                for kk, vv in nc.items():
                    outs[f"L{pi}/{kk}"] = vv
            return xc, outs

        x1, ncs = jax.lax.scan(body, x1, (stacked_p, stacked_c))
        for k, v in ncs.items():
            new_cache[f"blocks/{k}"] = v
    for ri, kind in enumerate(rem):
        lc = {k[len(f"rem{ri}/"):]: v for k, v in cache.items()
              if k.startswith(f"rem{ri}/")}
        x1, nc = _decode_layer(kind, sub(params, f"rem{ri}"), lc, x1, cfg, pos)
        for kk, vv in nc.items():
            new_cache[f"rem{ri}/{kk}"] = vv

    x1 = rms_norm(x1, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x1, head)[:, 0]
    return logits, new_cache
