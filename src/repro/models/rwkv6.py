"""RWKV6 (Finch) time-mix and channel-mix, with data-dependent decay
[arXiv:2404.05892].

Training path is the CHUNKED linear-attention form: within a chunk of length
C the pairwise decay matrix A[t,i,n] = exp(L[t-1,n] - L[i,n]) (i<t) is built
in log space — L is the inclusive cumulative log-decay, monotonically
decreasing, so every exponent is <= 0 and the computation is overflow-free
without FLA-style renormalization tricks.  Cross-chunk state S [N_k, N_v]
carries through a lax.scan.  ``repro.kernels.rwkv6_scan`` is the Pallas TPU
version of the same algorithm.

Decode path is the O(1) recurrence: out = r.(S + u*(k^T v)); S' = w*S + k^T v.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

CHUNK = 128


def _lerp(x, xprev, mu):
    return x + (xprev - x) * mu


def _token_shift(x, x_last=None):
    """Previous-token x; zeros (or carried state) at position 0."""
    first = jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def time_mix_params(reg, prefix, d, n_heads, head_dim, lora=64, dtype=jnp.float32):
    p = prefix
    for mu in ("mu_x", "mu_w", "mu_k", "mu_v", "mu_r", "mu_g"):
        reg.add(f"{p}/{mu}", (d,), ("embed",), zeros=True, dtype=dtype)
    for w in ("w_r", "w_k", "w_v", "w_g", "w_o"):
        reg.add(f"{p}/{w}", (d, d), ("embed", "heads"), dtype=dtype)
    reg.add(f"{p}/w0", (d,), ("heads",), zeros=True, dtype=dtype)
    reg.add(f"{p}/w_lora_a", (d, lora), ("embed", "lora"), dtype=dtype)
    reg.add(f"{p}/w_lora_b", (lora, d), ("lora", "heads"), dtype=dtype, scale=1e-2)
    reg.add(f"{p}/u", (n_heads, head_dim), ("heads", "head_dim"), zeros=True, dtype=dtype)
    reg.add(f"{p}/gn_g", (d,), ("heads",), zeros=True, dtype=dtype)
    reg.add(f"{p}/gn_b", (d,), ("heads",), zeros=True, dtype=dtype)


def channel_mix_params(reg, prefix, d, d_ff, dtype=jnp.float32):
    p = prefix
    reg.add(f"{p}/mu_k", (d,), ("embed",), zeros=True, dtype=dtype)
    reg.add(f"{p}/mu_r", (d,), ("embed",), zeros=True, dtype=dtype)
    reg.add(f"{p}/w_k", (d, d_ff), ("embed", "ff"), dtype=dtype)
    reg.add(f"{p}/w_v", (d_ff, d), ("ff", "embed"), dtype=dtype)
    reg.add(f"{p}/w_r", (d, d), ("embed", "heads"), dtype=dtype)


def _project(p, x, xprev):
    """Shared projection math for train & decode: returns r,k,v,g,logw."""
    xw = _lerp(x, xprev, p["mu_w"])
    xk = _lerp(x, xprev, p["mu_k"])
    xv = _lerp(x, xprev, p["mu_v"])
    xr = _lerp(x, xprev, p["mu_r"])
    xg = _lerp(x, xprev, p["mu_g"])
    r = jnp.einsum("...d,dk->...k", xr, p["w_r"])
    k = jnp.einsum("...d,dk->...k", xk, p["w_k"])
    v = jnp.einsum("...d,dk->...k", xv, p["w_v"])
    g = jax.nn.silu(jnp.einsum("...d,dk->...k", xg, p["w_g"]))
    # data-dependent decay (the Finch contribution): per-channel, per-token
    dd = jnp.einsum(
        "...l,ld->...d", jnp.tanh(jnp.einsum("...d,dl->...l", xw, p["w_lora_a"])),
        p["w_lora_b"],
    )
    logw = -jnp.exp(jnp.clip(p["w0"] + dd, -8.0, 6.0).astype(jnp.float32))
    return r, k, v, g, logw


def _group_norm(x, g, b, n_heads, eps=1e-5):
    """Per-head LayerNorm of the wkv output (RWKV GroupNorm(H))."""
    b_, t, d = x.shape
    xh = x.reshape(b_, t, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, -1, keepdims=True)
    var = jnp.var(xh, -1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b_, t, d) * (1.0 + g) + b).astype(x.dtype)


def time_mix(p, x, n_heads: int, head_dim: int, state=None, x_last=None,
             chunk: int = CHUNK):
    """x: [B,T,D]. Returns (out [B,T,D], (state [B,H,N,N], x_last [B,D]))."""
    bsz, t, d = x.shape
    h, n = n_heads, head_dim
    xprev = _token_shift(x, x_last)
    r, k, v, g, logw = _project(p, x, xprev)

    pad = (-t) % chunk
    if pad:
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0))) for a in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad
    nc = tt // chunk

    def to_chunks(a):
        return a.reshape(bsz, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)  # [nc,B,H,C,N]

    # r/k/v stay in model dtype (bf16 in production): the [C,C,N] pairwise
    # tensor A inherits it, halving the dominant HBM traffic (§Perf); all
    # contractions still accumulate in f32 via preferred_element_type
    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = logw.reshape(bsz, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
    u = p["u"].astype(jnp.float32)  # [H,N]

    s0 = (jnp.zeros((bsz, h, n, n), jnp.float32) if state is None
          else state.astype(jnp.float32))

    # nested remat: without it, differentiating the chunk scan saves the
    # [nc,B,H,C,C,N] pairwise decay tensor for EVERY chunk (10 GiB/chip at
    # 4k x 40H); rematerializing per chunk keeps only the [B,H,N,N] carries
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body_remat(s, inp):
        r_, k_, v_, lw_ = inp                       # [B,H,C,N]
        dt = r_.dtype
        L = jnp.cumsum(lw_, axis=2)                 # inclusive cumulative log decay
        Lprev = L - lw_                             # L_{t-1} (exclusive), row t
        # carry-in: r_t * exp(L_{t-1}) @ S
        rdec = r_.astype(jnp.float32) * jnp.exp(Lprev)
        carry_out = jnp.einsum("bhtn,bhnm->bhtm", rdec, s)
        # intra-chunk: A[t,i,n] = exp(L[t-1,n] - L[i,n]), i < t  (always <= 0)
        expo = Lprev[:, :, :, None, :] - L[:, :, None, :, :]
        A = jnp.exp(jnp.clip(expo, -60.0, 0.0)).astype(dt)
        mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        scores = jnp.einsum("bhtn,bhin,bhtin->bhti", r_, k_, A,
                            preferred_element_type=jnp.float32) * mask
        intra = jnp.einsum("bhti,bhim->bhtm", scores.astype(dt), v_,
                           preferred_element_type=jnp.float32)
        # u bonus (i == t)
        bonus = jnp.einsum("bhtn,bhtn,hn->bht", r_.astype(jnp.float32),
                           k_.astype(jnp.float32), u)
        out = carry_out + intra + bonus[..., None] * v_.astype(jnp.float32)
        # state update: S' = diag(exp(L_C)) S + sum_i exp(L_C - L_i) k_i (x) v_i
        Lc = L[:, :, -1:, :]                        # [B,H,1,N]
        kdec = (k_.astype(jnp.float32) * jnp.exp(Lc - L)).astype(dt)
        s_new = s * jnp.exp(Lc[:, :, 0, :])[..., None] + jnp.einsum(
            "bhin,bhim->bhnm", kdec, v_, preferred_element_type=jnp.float32
        )
        return s_new, out

    def body(s, inp):
        return body_remat(s, inp)

    s_fin, outs = jax.lax.scan(body, s0, (rc, kc, vc, lw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(bsz, tt, d)[:, :t]
    out = _group_norm(out, p["gn_g"], p["gn_b"], h) * g
    out = jnp.einsum("btd,dk->btk", out.astype(x.dtype), p["w_o"])
    return out, (s_fin.astype(jnp.float32), x[:, -1])


def time_mix_decode(p, x1, state, x_last, n_heads: int, head_dim: int):
    """One-token decode. x1: [B,1,D]; state [B,H,N,N]; x_last [B,D]."""
    bsz, _, d = x1.shape
    h, n = n_heads, head_dim
    xprev = x_last[:, None]
    r, k, v, g, logw = _project(p, x1, xprev)
    rh = r.reshape(bsz, h, n).astype(jnp.float32)
    kh = k.reshape(bsz, h, n).astype(jnp.float32)
    vh = v.reshape(bsz, h, n).astype(jnp.float32)
    w = jnp.exp(logw.reshape(bsz, h, n))
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhn,bhm->bhnm", kh, vh)
    out = jnp.einsum("bhn,bhnm->bhm", rh, state + u[None, :, :, None] * kv)
    s_new = state * w[..., None] + kv
    out = out.reshape(bsz, 1, d)
    out = _group_norm(out, p["gn_g"], p["gn_b"], h) * g
    out = jnp.einsum("btd,dk->btk", out.astype(x1.dtype), p["w_o"])
    return out, (s_new, x1[:, 0])


def channel_mix(p, x, x_last=None):
    """Squared-ReLU channel mix. Returns (out, new x_last)."""
    xprev = _token_shift(x, x_last)
    xk = _lerp(x, xprev, p["mu_k"])
    xr = _lerp(x, xprev, p["mu_r"])
    k = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", xk, p["w_k"])))
    kv = jnp.einsum("...f,fd->...d", k, p["w_v"])
    out = jax.nn.sigmoid(jnp.einsum("...d,dk->...k", xr, p["w_r"])) * kv
    return out, x[:, -1]


def channel_mix_decode(p, x1, x_last):
    out, new_last = channel_mix(p, x1, x_last)
    return out, new_last
