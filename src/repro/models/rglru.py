"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: W_x -> causal depthwise conv1d(width 4) -> RG-LRU, gated by a GeLU
branch, projected back.  The RG-LRU diagonal recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    log a_t = -c * softplus(Lambda) * r_t,   c = 8

runs as a jax.lax.associative_scan over time (fully parallel, O(T log T)
elementwise work on a [T, d_rnn] state — no quadratic term, which is what
makes the arch long_500k-eligible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

RG_C = 8.0


def rglru_params(reg, prefix, d, d_rnn, conv_width=4, dtype=jnp.float32):
    p = prefix
    reg.add(f"{p}/w_x", (d, d_rnn), ("embed", "rnn"), dtype=dtype)
    reg.add(f"{p}/w_gate", (d, d_rnn), ("embed", "rnn"), dtype=dtype)
    reg.add(f"{p}/w_out", (d_rnn, d), ("rnn", "embed"), dtype=dtype)
    reg.add(f"{p}/conv_w", (conv_width, d_rnn), ("conv", "rnn"), dtype=dtype,
            scale=0.5)
    reg.add(f"{p}/conv_b", (d_rnn,), ("rnn",), zeros=True, dtype=dtype)
    reg.add(f"{p}/w_a", (d_rnn, d_rnn), ("rnn", "rnn2"), dtype=dtype, scale=1e-2)
    reg.add(f"{p}/b_a", (d_rnn,), ("rnn",), zeros=True, dtype=dtype)
    reg.add(f"{p}/w_i", (d_rnn, d_rnn), ("rnn", "rnn2"), dtype=dtype, scale=1e-2)
    reg.add(f"{p}/b_i", (d_rnn,), ("rnn",), zeros=True, dtype=dtype)
    reg.add(f"{p}/lam", (d_rnn,), ("rnn",), zeros=True, dtype=dtype)


def _conv1d_causal(x, w, b, state=None):
    """Depthwise causal conv; x [B,T,C], w [W,C]. state: [B,W-1,C] history."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return out + b, xp[:, -(width - 1):]  # (out, new conv state)


def _rglru_gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("...c,cd->...d", u, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...c,cd->...d", u, p["w_i"]) + p["b_i"])
    log_a = (-RG_C * jax.nn.softplus(p["lam"]) * r).astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * u).astype(jnp.float32)
    return a, gated


def rglru_block(p, x, h0=None, conv_state=None):
    """x: [B,T,D] -> (out [B,T,D], (h_last [B,d_rnn], conv_state))."""
    u = jnp.einsum("btd,dc->btc", x, p["w_x"])
    u, conv_state_new = _conv1d_causal(u, p["conv_w"], p["conv_b"], conv_state)
    a, gated = _rglru_gates(p, u)

    if h0 is not None:  # fold carried state into step 0: h_0' contribution
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = h.astype(x.dtype)
    gate = jax.nn.gelu(jnp.einsum("btd,dc->btc", x, p["w_gate"]))
    out = jnp.einsum("btc,cd->btd", h * gate, p["w_out"])
    return out, (h[:, -1], conv_state_new)


def rglru_decode(p, x1, h, conv_state):
    """One-token step. x1 [B,1,D]; h [B,d_rnn]; conv_state [B,W-1,d_rnn]."""
    u = jnp.einsum("btd,dc->btc", x1, p["w_x"])
    u, conv_state_new = _conv1d_causal(u, p["conv_w"], p["conv_b"], conv_state)
    a, gated = _rglru_gates(p, u)
    h_new = a[:, 0] * h.astype(jnp.float32) + gated[:, 0]
    gate = jax.nn.gelu(jnp.einsum("btd,dc->btc", x1, p["w_gate"]))
    out = jnp.einsum("btc,cd->btd", h_new[:, None].astype(x1.dtype) * gate, p["w_out"])
    return out, (h_new, conv_state_new)
