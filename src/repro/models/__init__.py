"""Unified model API over all assigned architectures.

``init / loss_fn / decode_step / init_cache`` dispatch on cfg.family so the
trainer, server, and dry-run treat every arch uniformly.
"""
from __future__ import annotations

from typing import Dict, Tuple

from . import lm as _lm
from . import whisper as _wh


def init(cfg, key) -> Tuple[Dict, Dict]:
    if cfg.family == "audio":
        return _wh.init_whisper(cfg, key)
    return _lm.init_lm(cfg, key)


def loss_fn(cfg, params: Dict, batch: Dict):
    if cfg.family == "audio":
        return _wh.whisper_loss(cfg, params, batch)
    return _lm.lm_loss(cfg, params, batch)


def forward(cfg, params: Dict, batch: Dict):
    if cfg.family == "audio":
        return _wh.whisper_forward(cfg, params, batch["enc_embeds"], batch["tokens"])
    return _lm.lm_forward(cfg, params, batch["tokens"],
                          patch_embeds=batch.get("patch_embeds"))


def init_cache(cfg, batch: int, cache_len: int) -> Dict:
    if cfg.family == "audio":
        return _wh.init_whisper_cache(cfg, batch, cache_len)
    return _lm.init_decode_cache(cfg, batch, cache_len)


def decode_step(cfg, params: Dict, cache: Dict, token, pos):
    if cfg.family == "audio":
        return _wh.whisper_decode_step(cfg, params, cache, token, pos)
    return _lm.lm_decode_step(cfg, params, cache, token, pos)
