"""Mixture-of-experts FFN with sort-based capacity dispatch.

The expert dimension is the LM-side analogue of the paper's quantum-number
blocks (DESIGN.md Sec. 4): tokens route to blocks, and we provide the same
two execution strategies the paper contrasts:

* ``dispatch="sorted"`` (default, the *sparse-sparse* analogue): within each
  sequence, token slots are sorted by expert id and packed into a static
  [E, C, d] buffer (C = ceil(S*k/E * capacity_factor)); the expert FFN is one
  batched GEMM — a single "contraction call" with precomputed output
  structure, flop count proportional to ACTIVE parameters only.
* ``dispatch="dense"`` (the *sparse-dense* analogue): every token through
  every expert, masked combine.  Dense-GEMM-friendly but E/k times the
  flops; used in tests as the oracle.

Dispatch is LOCAL to the batch dim (each sequence sorts/packs its own S*k
slots), so under data parallelism no cross-chip sort or scatter ever happens;
experts shard over "model" (EP) when the expert count divides it, else the
expert FFN width shards (TP fallback) — see launch/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import batch_axes, shard_hint

# EP (experts over "model", when divisible) vs TP (expert-ff over "model")
# activation layout — §Perf hillclimb knob; EP is the default/baseline.
EXPERT_PARALLEL = True


def _buf_hint(x):
    if EXPERT_PARALLEL:
        return shard_hint(x, batch_axes(), "model", None, None)
    return shard_hint(x, batch_axes(), None, None, None)


def _h_hint(x):
    if EXPERT_PARALLEL:
        return shard_hint(x, batch_axes(), "model", None, "model")
    return shard_hint(x, batch_axes(), None, None, "model")


def moe_ffn(x, w_router, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float = 1.25, dispatch: str = "sorted"):
    """x: [B,S,D]; w_router: [D,E]; w_gate/up: [E,D,F]; w_down: [E,F,D]."""
    b, s, d = x.shape
    e = w_router.shape[1]
    logits = jnp.einsum("bsd,de->bse", x, w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, top_k)           # [B,S,k]
    gate_w = (gate_w / jnp.sum(gate_w, -1, keepdims=True)).astype(x.dtype)

    if dispatch == "dense":
        h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, w_gate))
        h = h * jnp.einsum("bsd,edf->bsef", x, w_up)
        y_all = jnp.einsum("bsef,efd->bsed", h, w_down)    # [B,S,E,D]
        onehot = jax.nn.one_hot(gate_i, e, dtype=x.dtype)  # [B,S,k,E]
        comb = jnp.einsum("bsk,bske->bse", gate_w, onehot)
        return jnp.einsum("bse,bsed->bsd", comb, y_all)

    # ---- sorted dispatch, local per sequence ------------------------------
    cap = int(np.ceil(s * top_k / e * capacity_factor))
    n_slots = s * top_k
    flat_e = gate_i.reshape(b, n_slots)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(s), top_k), (1,))  # [n_slots]
    flat_w = gate_w.reshape(b, n_slots)

    order = jnp.argsort(flat_e, axis=1)                    # stable per row
    se = jnp.take_along_axis(flat_e, order, axis=1)        # [B, n_slots]
    st = jnp.take(flat_t, order)                           # token of each slot
    sw = jnp.take_along_axis(flat_w, order, axis=1)

    # rank within expert group = slot index - group start offset
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    rank = jnp.arange(n_slots)[None, :] - jnp.take_along_axis(starts, se, axis=1)
    keep = rank < cap                                      # overflow drops
    dest = jnp.where(keep, se * cap + rank, e * cap)       # e*cap = trash row

    # vmapped row-wise gather/scatter: indices stay [B, n_slots] (never
    # broadcast over D), lowering to gather/scatter with batching dims that
    # SPMD shards cleanly on the batch axis
    gather_rows = jax.vmap(lambda rows, idx: jnp.take(rows, idx, axis=0))
    xs = gather_rows(x, st)                                # [B, n_slots, D]

    def scatter_rows(dst, idx, val):
        return dst.at[idx].set(val)

    buf = jax.vmap(scatter_rows)(
        jnp.zeros((b, e * cap + 1, d), x.dtype), dest,
        xs * keep[..., None].astype(x.dtype),
    )
    buf = buf[:, :-1].reshape(b, e, cap, d)
    buf = _buf_hint(buf)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, w_gate))
    h = _h_hint(h)
    h = h * jnp.einsum("becd,edf->becf", buf, w_up)
    y = jnp.einsum("becf,efd->becd", h, w_down)
    y = _buf_hint(y)
    y = y.reshape(b, e * cap, d)

    yg = gather_rows(y, jnp.minimum(dest, e * cap - 1))
    yg = yg * (keep[..., None] * sw[..., None]).astype(x.dtype)
    out = jax.vmap(lambda idx, val: jnp.zeros((s, d), x.dtype).at[idx].add(val))(
        st, yg
    )
    return out


def aux_load_balance_loss(logits: jax.Array, top_k: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean_e f_e * p_e * E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    e = probs.shape[-1]
    _, idx = jax.lax.top_k(probs, top_k)
    hard = jnp.sum(jax.nn.one_hot(idx, e), axis=-2)  # [T,E]
    f = jnp.mean(hard, axis=tuple(range(hard.ndim - 1)))
    p = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(f * p) / top_k
