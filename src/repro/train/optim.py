"""AdamW + gradient clipping + LR schedules, functional (no optax dependency).

Optimizer state is a flat dict mirroring the param dict ("m/<path>",
"v/<path>", "step"), so the same logical-axis sharding rules apply to the
moments as to the parameters (fully sharded optimizer state under FSDP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def init_opt_state(params: Dict) -> Dict:
    st = {"step": jnp.zeros((), jnp.int32)}
    for k, v in params.items():
        st[f"m/{k}"] = jnp.zeros_like(v, dtype=jnp.float32)
        st[f"v/{k}"] = jnp.zeros_like(v, dtype=jnp.float32)
    return st


def opt_state_axes(axes: Dict) -> Dict:
    out = {"step": ()}
    for k, a in axes.items():
        out[f"m/{k}"] = a
        out[f"v/{k}"] = a
    return out


def lr_at(oc: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def global_norm(grads: Dict):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in grads.values())
    )


def adamw_update(
    oc: OptConfig, params: Dict, grads: Dict, state: Dict
) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))
    lr = lr_at(oc, step)
    b1c = 1 - oc.beta1 ** step.astype(jnp.float32)
    b2c = 1 - oc.beta2 ** step.astype(jnp.float32)

    new_params, new_state = {}, {"step": step}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * clip
        m = oc.beta1 * state[f"m/{k}"] + (1 - oc.beta1) * g
        v = oc.beta2 * state[f"v/{k}"] + (1 - oc.beta2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + oc.eps)
        decay = oc.weight_decay if p.ndim > 1 else 0.0  # no decay on norms/biases
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + decay * pf)
        new_params[k] = pf.astype(p.dtype)
        new_state[f"m/{k}"] = m
        new_state[f"v/{k}"] = v
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
