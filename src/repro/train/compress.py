"""Gradient compression with error feedback (distributed-optimization trick).

For cross-pod data parallelism the gradient all-reduce over the (slow)
pod-interconnect dominates; compressing to bf16 or int8 with error feedback
(Seide et al. '14, Karimireddy et al. '19) cuts wire bytes 2-4x while keeping
convergence: the quantization residual is carried into the next step, so the
compounded error stays bounded.

``compressed_grads`` quantizes+dequantizes with error feedback; in the train
step it runs BEFORE the optimizer, placed so XLA's cross-pod reduce happens on
the low-precision values (the within-pod reduce stays full precision).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params: Dict) -> Dict:
    return {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}


def _quantize(g: jax.Array, mode: str) -> jax.Array:
    if mode == "bf16":
        return g.astype(jnp.bfloat16).astype(jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    raise ValueError(mode)


def compressed_grads(
    grads: Dict, err: Dict, mode: str = "bf16"
) -> Tuple[Dict, Dict]:
    """Returns (dequantized grads as reduced on the wire, new error state)."""
    out, new_err = {}, {}
    for k, g in grads.items():
        g = g.astype(jnp.float32) + err[k]     # error feedback
        q = _quantize(g, mode)
        out[k] = q
        new_err[k] = g - q
    return out, new_err


def wire_bytes_saved(params: Dict, mode: str) -> int:
    """Bytes saved per gradient reduce vs float32."""
    total = sum(int(v.size) for v in params.values())
    per = {"bf16": 2, "int8": 1}[mode]
    return total * (4 - per)
