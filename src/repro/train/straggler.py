"""Straggler and step-time anomaly monitoring.

In SPMD training a slow host stalls every collective, so stragglers manifest
as global step-time spikes.  The monitor keeps an EWMA + variance of step
times and flags anomalies; the trainer's policy on a flagged step is
(1) log it, (2) after ``evict_after`` consecutive anomalies, request a
checkpoint-and-restart (on a real cluster the scheduler would then cordon the
slow host; in-process we surface the signal).  This is the standard
large-fleet mitigation — detect fast, restart from the last complete
checkpoint, resume with the same data-pipeline state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    seconds: float
    ewma: float
    threshold: float
    consecutive: int
    evict: bool


class StepMonitor:
    def __init__(self, alpha: float = 0.1, sigma_mult: float = 4.0,
                 warmup: int = 5, evict_after: int = 3):
        self.alpha = alpha
        self.sigma_mult = sigma_mult
        self.warmup = warmup
        self.evict_after = evict_after
        self.ewma: Optional[float] = None
        self.ewvar: float = 0.0
        self.n = 0
        self.consecutive = 0
        self.reports: List[StragglerReport] = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, seconds: Optional[float] = None) -> Optional[StragglerReport]:
        if seconds is None:
            assert self._t0 is not None
            seconds = time.perf_counter() - self._t0
        self.n += 1
        if self.ewma is None:
            self.ewma = seconds
            return None
        thresh = self.ewma + self.sigma_mult * max(self.ewvar, 0.05 * self.ewma)
        is_anomaly = self.n > self.warmup and seconds > thresh
        if is_anomaly:
            self.consecutive += 1
            rep = StragglerReport(
                step=step, seconds=seconds, ewma=self.ewma, threshold=thresh,
                consecutive=self.consecutive,
                evict=self.consecutive >= self.evict_after,
            )
            self.reports.append(rep)
        else:
            self.consecutive = 0
            rep = None
        # only fold non-anomalous steps into the running stats
        if not is_anomaly:
            d = seconds - self.ewma
            self.ewma += self.alpha * d
            self.ewvar = (1 - self.alpha) * (self.ewvar + self.alpha * abs(d))
        return rep
