"""Deterministic, restorable synthetic token pipeline.

Production trainers need a data source whose state can be checkpointed and
restored exactly (fault tolerance) and that is cheap enough never to
bottleneck the accelerators.  This pipeline generates structured synthetic
sequences (a mixture of Zipfian unigrams and copy/induction motifs, so models
actually reduce loss on it) from a counter-based PRNG: state == (seed, step),
which makes restore-after-restart exact and O(1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d: Dict) -> "DataState":
        return DataState(int(d["seed"]), int(d["step"]))


class SyntheticLM:
    """Batch generator: tokens [B, S+1] -> (inputs, labels) pairs."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2, motif_frac: float = 0.3):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.state = DataState(seed, 0)
        # Zipfian unigram table (stable across restarts)
        ranks = np.arange(1, vocab_size + 1)
        p = 1.0 / ranks**zipf_a
        self.probs = p / p.sum()
        self.motif_frac = motif_frac

    def __iter__(self) -> Iterator[Dict]:
        return self

    def __next__(self) -> Dict:
        rng = np.random.default_rng((self.state.seed, self.state.step))
        self.state.step += 1
        toks = rng.choice(self.vocab, size=(self.batch, self.seq + 1),
                          p=self.probs).astype(np.int32)
        # induction motifs: copy a random span forward (gives the model
        # something learnable beyond unigram statistics)
        n_motif = int(self.batch * self.motif_frac)
        if n_motif and self.seq >= 16:
            span = min(8, self.seq // 4)
            src = rng.integers(0, self.seq // 2 - span, size=n_motif)
            dst = rng.integers(self.seq // 2, self.seq + 1 - span, size=n_motif)
            rows = rng.choice(self.batch, size=n_motif, replace=False)
            for r, s_, d_ in zip(rows, src, dst):
                toks[r, d_ : d_ + span] = toks[r, s_ : s_ + span]
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict):
        self.state = DataState.from_dict(d)
