"""Fault-tolerant, mesh-independent, asynchronous checkpointing.

Design (DESIGN.md Sec. 5):
  * crash consistency — arrays + manifest are written to a temp dir, fsynced,
    then atomically renamed to ``step_N``; a partial write can never be
    mistaken for a checkpoint, so restart always finds the last COMPLETE step;
  * mesh independence (elastic scaling) — arrays are stored with their
    logical (global) shapes; ``restore`` re-shards onto whatever mesh/sharding
    the resumed job uses (grow or shrink the pod between runs);
  * async — ``save_async`` snapshots device arrays to host, then writes in a
    background thread so the train loop never blocks on the filesystem;
  * bounded retention — keep the newest ``keep`` checkpoints.

On a real multi-host pod each host writes only the shards it owns (the
manifest records shard ownership); in this single-process container that
degenerates to one writer, but the format and restore path are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, arrays: Dict[str, jax.Array],
             meta: Optional[Dict] = None):
        """Blocking save of a flat dict of arrays + JSON-able metadata."""
        host = {k: np.asarray(v) for k, v in arrays.items()}
        self._write(step, host, meta or {})

    def save_async(self, step: int, arrays: Dict[str, jax.Array],
                   meta: Optional[Dict] = None):
        """Snapshot to host now, write in the background."""
        self.wait()  # one in-flight checkpoint at a time
        host = {k: np.asarray(v) for k, v in arrays.items()}
        meta = dict(meta or {})

        def work():
            try:
                self._write(step, host, meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=self.dir))
        try:
            np.savez(tmp / "arrays.npz", **host)
            manifest = dict(
                step=step,
                time=time.time(),
                arrays={k: dict(shape=list(v.shape), dtype=str(v.dtype))
                        for k, v in host.items()},
                meta=meta,
            )
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self.dir / f"step_{step:010d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic completion marker
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Dict] = None):
        """Returns (step, arrays, meta); arrays re-sharded per ``shardings``
        (path -> Sharding), enabling restore onto a different mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        arrays = {}
        for k in manifest["arrays"]:
            v = data[k]
            if shardings and k in shardings:
                arrays[k] = jax.device_put(v, shardings[k])
            else:
                arrays[k] = jax.numpy.asarray(v)
        return step, arrays, manifest["meta"]
