"""Persistent plan + executable store: cold starts at warm-cache speed.

A fresh worker process pays the full plan/trace/compile pipeline on its
first sweep — ~20x a steady-state sweep (benchmarks/bench_dist.json) even
though every artifact it builds is a pure function of block *structure* the
previous worker already derived.  This module persists all three layers of
that pipeline across processes:

1. **Plan tables** (``ContractionPlan`` / ``DecompositionPlan`` /
   ``EnvironmentPlan``): pure Index/numpy metadata, already keyed by
   structural signature in the ``_SignatureLRU`` caches (dist/plan.py).
   ``PlanStore`` maps a canonicalized signature digest to a pickled,
   version-gated entry on disk; the LRU caches consult it on miss and write
   back on build, so a primed store means zero plan builds.
2. **Compiled executables** via the JAX persistent compilation cache
   (``jax_compilation_cache_dir``): ``enable_compilation_cache`` points it
   at ``store.compile_cache_dir`` with the entry-size/compile-time floors
   dropped so the many small DMRG cores all qualify.  XLA then skips
   *compilation* of any program it has seen, in any process.
3. **Traced cores** via ``jax.export``: the padded bucket cores (batched
   SVD core, output-slice core, fused env core) are exported to StableHLO
   keyed by (plan signature, core params, operand avals, jax fingerprint).
   A fresh process deserializes and wraps ``exported.call`` in ``jax.jit``
   — skipping the Python re-trace of the core body entirely (layer 2 then
   skips the XLA compile).  Export is strictly best-effort: any failure to
   export, serialize or deserialize is counted and falls back to a plain
   re-trace, never an error.

Store layout (``PlanStore(root)``)::

    root/
      contraction/<digest>.pkl   one entry per canonical plan signature
      decomp/<digest>.pkl
      env/<digest>.pkl
      exports/<digest>.pkl       serialized jax.export artifacts, or
                                 refusal tombstones for unexportable cores
      xla/                       the JAX persistent compilation cache

Every entry is written with the ``core/checkpoint.py`` idiom — mkstemp in
the target directory, write, flush, fsync, ``os.replace`` — so concurrent
writers (two workers priming the same store) race atomically: last writer
wins with a complete file, readers never observe a torn entry.

Version + signature gating: each entry records ``PERSIST_VERSION`` and its
canonical signature; a load checks both (and the jax fingerprint, for
exports) and treats any mismatch — or any unpickling error from a
truncated/corrupt file — as a miss, counted in ``stats()``, never a crash.
The store trusts its own directory (entries are pickles): point it only at
paths you would trust a checkpoint from.

Signature canonicalization: ``Index.__eq__``/``__hash__`` exclude the
``name`` field, so two structurally-identical tensors with differently
named indices share one in-memory cache slot.  The on-disk digest must
honor the same contract, so ``canonical_signature`` recursively rewrites
every ``Index`` to its ``(sectors, flow)`` pair before hashing — names can
never fragment (or alias) the store.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import pickle
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

from ..tensor.qn import Index
from . import plan as _plan_mod
from .plan import (
    global_decomp_cache,
    global_env_cache,
    global_plan_cache,
)

# Bump on ANY change to plan dataclass layout, signature canonicalization or
# entry schema: old stores are then rejected wholesale (counted as ``stale``)
# and rebuilt, never misread.
PERSIST_VERSION = 1

# subdirectory per plan kind; the kind string is also stored in each entry
# and checked on load, so a digest collision across kinds cannot alias
PLAN_KINDS = ("contraction", "decomp", "env")


def canonical_signature(sig: Any) -> Any:
    """Rewrite a structural signature into its name-free canonical form.

    Recursively maps ``Index -> ("Ix", sectors, flow)`` (dropping ``name``,
    which Index equality already excludes) and preserves tuple structure;
    ints, strings and charges pass through.  Two signatures compare equal
    under the in-memory caches iff their canonical forms are equal, so the
    canonical form is what the store digests and verifies.
    """
    if isinstance(sig, Index):
        return ("Ix", sig.sectors, sig.flow)
    if isinstance(sig, tuple):
        return tuple(canonical_signature(x) for x in sig)
    return sig


def signature_digest(sig: Any) -> str:
    """Stable hex digest of a signature's canonical form (store filename)."""
    canon = canonical_signature(sig)
    return hashlib.sha256(repr(canon).encode()).hexdigest()


def _atomic_write_bytes(path: str, payload: bytes) -> None:
    """The core/checkpoint.py idiom: tmp file in the target dir + rename."""
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise


def jax_fingerprint() -> Tuple[str, bool, str]:
    """Environment key for exported executables: (jax version, x64, backend).

    An exported StableHLO artifact bakes in dtypes (x64) and lowering
    choices that may shift across jax releases or backends, so exports are
    only replayed in an identical environment; plans (pure numpy) need no
    fingerprint.
    """
    import jax

    return (jax.__version__, bool(jax.config.jax_enable_x64), jax.default_backend())


def _aval_fingerprint(args: Any) -> Any:
    """(shape, dtype) per flattened leaf of the example args.

    Leaves only, no treedef: exports replay only on exact aval match, and
    the caller's structural key already pins the container structure.  (A
    mapped *tree* would reconstruct custom pytree nodes — e.g.
    BlockSparseTensor — whose repr embeds a memory address, making the
    digest process-unstable.)
    """
    import jax

    return tuple(
        (tuple(x.shape), str(x.dtype))
        for x in jax.tree_util.tree_leaves(args)
    )


_pytree_serialization_ready = False


def _ensure_pytree_serialization() -> bool:
    """Register BlockSparseTensor for jax.export treedef serialization.

    Exported artifacts whose in/out trees contain custom pytree nodes can
    only be serialized once the node type is registered; the aux data
    (indices, charge, block keys) is pure metadata, so pickle round-trips
    it.  Idempotent; returns False (export path disabled) if this jax
    version lacks the registration API.
    """
    global _pytree_serialization_ready
    if _pytree_serialization_ready:
        return True
    try:
        from jax import export as jax_export

        from ..tensor.blocksparse import BlockSparseTensor

        jax_export.register_pytree_node_serialization(
            BlockSparseTensor,
            serialized_name="repro.tensor.BlockSparseTensor",
            serialize_auxdata=lambda aux: pickle.dumps(
                aux, protocol=pickle.HIGHEST_PROTOCOL
            ),
            deserialize_auxdata=pickle.loads,
        )
    except ValueError:
        pass  # already registered (e.g. two stores in one process)
    except Exception:
        return False
    _pytree_serialization_ready = True
    return True


class PlanStore:
    """Versioned on-disk store for plan tables and exported cores.

    Thread-safe (one lock guards the counters; file operations are atomic
    on their own) and multi-process-safe (atomic writes, tolerant reads).
    All counters are cumulative per store *instance*; ``stats()`` snapshots
    them.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(os.fspath(root))
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        # plan-entry counters
        self.hits = 0          # entry found, version + signature verified
        self.misses = 0        # no entry on disk
        self.saves = 0         # entries written
        self.corrupt = 0       # unreadable / truncated / wrong-kind entries
        self.stale = 0         # version-mismatch rejections
        # export counters
        self.export_hits = 0
        self.export_misses = 0
        self.export_saves = 0
        self.export_failures = 0   # export/serialize attempts that failed
        self.export_corrupt = 0    # unreadable or mismatched export entries
        self.export_prefetched = 0  # artifacts scheduled by prefetch_exports
        # in-process memo over export entries, keyed by entry path:
        # value is ("fn", full_key, callable) | ("refused", full_key, None),
        # or a Future resolving to one (prefetch_exports).  Serves repeat
        # lookups and refusal tombstones without touching disk again.
        self._memo: Dict[str, Any] = {}

    # ---------------------------------------------------------------- layout
    @property
    def compile_cache_dir(self) -> str:
        """Directory for the JAX persistent compilation cache (created)."""
        d = os.path.join(self.root, "xla")
        os.makedirs(d, exist_ok=True)
        return d

    def _plan_path(self, kind: str, sig: Any) -> str:
        assert kind in PLAN_KINDS, kind
        return os.path.join(self.root, kind, signature_digest(sig) + ".pkl")

    def _export_path(self, key: Any) -> str:
        return os.path.join(self.root, "exports", signature_digest(key) + ".pkl")

    # ----------------------------------------------------------- plan entries
    def load_plan(self, kind: str, sig: Any):
        """Fetch the plan stored for ``sig``, or None (miss/corrupt/stale).

        Never raises on a bad entry: truncated pickles, foreign payloads,
        version or signature mismatches all count and return None — the
        caller rebuilds and (on save) atomically repairs the entry.
        """
        path = self._plan_path(kind, sig)
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
        except FileNotFoundError:
            with self._lock:
                self.misses += 1
            return None
        except Exception:
            with self._lock:
                self.corrupt += 1
            return None
        if not isinstance(entry, dict) or entry.get("version") != PERSIST_VERSION:
            with self._lock:
                self.stale += 1
            return None
        if (
            entry.get("kind") != kind
            or entry.get("signature") != canonical_signature(sig)
            or "plan" not in entry
        ):
            with self._lock:
                self.corrupt += 1
            return None
        with self._lock:
            self.hits += 1
        return entry["plan"]

    def save_plan(self, kind: str, sig: Any, plan: Any) -> bool:
        """Atomically persist ``plan`` under ``sig``; False on any IO error.

        Contraction plans get their lazy layouts materialized first (see
        ``ContractionPlan.materialize``): the priming process derives them
        once, loaders never do.
        """
        if hasattr(plan, "materialize"):
            with contextlib.suppress(Exception):
                plan.materialize()
        entry = {
            "version": PERSIST_VERSION,
            "kind": kind,
            "signature": canonical_signature(sig),
            "plan": plan,
        }
        try:
            _atomic_write_bytes(
                self._plan_path(kind, sig),
                pickle.dumps(entry, protocol=pickle.HIGHEST_PROTOCOL),
            )
        except Exception:
            return False
        with self._lock:
            self.saves += 1
        return True

    # --------------------------------------------------------------- exports
    def load_export(self, key: Any, example_args: Any):
        """Deserialize the exported core stored under ``key``, jit-wrapped.

        ``key`` is any picklable structure identifying the core (plan
        signature + core kind + static params); the jax fingerprint and the
        example-arg avals are folded in, so a hit is only possible in an
        identical environment with identical operand shapes.  Returns a
        callable or None; never raises.
        """
        if not _ensure_pytree_serialization():
            with self._lock:
                self.export_misses += 1
            return None
        full_key = (canonical_signature(key), jax_fingerprint(),
                    _aval_fingerprint(example_args))
        path = self._export_path(full_key)
        memo = self._resolve_memo(path)
        if memo is not None and memo[1] == full_key:
            tag, _, fn = memo
            with self._lock:
                if tag == "fn":
                    self.export_hits += 1
                else:  # refusal tombstone: behaves as a miss, but
                    # save_export will skip the doomed re-export
                    self.export_misses += 1
            return fn if tag == "fn" else None
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
        except FileNotFoundError:
            with self._lock:
                self.export_misses += 1
            return None
        except Exception:
            with self._lock:
                self.export_corrupt += 1
            return None
        try:
            if (
                not isinstance(entry, dict)
                or entry.get("version") != PERSIST_VERSION
                or entry.get("key") != full_key
            ):
                raise ValueError("export entry mismatch")
            if entry.get("refused"):
                self._memo[path] = ("refused", full_key, None)
                with self._lock:
                    self.export_misses += 1
                return None
            import jax
            from jax import export as jax_export

            exported = jax_export.deserialize(entry["data"])
            fn = jax.jit(exported.call)
        except Exception:
            with self._lock:
                self.export_corrupt += 1
            return None
        self._memo[path] = ("fn", full_key, fn)
        with self._lock:
            self.export_hits += 1
        return fn

    def _resolve_memo(self, path: str):
        """The memo entry for ``path`` as a resolved tuple, or None.

        Blocks on an in-flight prefetch Future: waiting on the background
        deserialize+compile is still cheaper than redoing it inline.
        """
        m = self._memo.get(path)
        if m is None:
            return None
        if hasattr(m, "result"):
            try:
                m = m.result()
            except Exception:
                m = None
            self._memo[path] = m  # collapse the Future (even to None)
        return m

    def save_export(self, key: Any, fn, example_args: Any) -> bool:
        """Best-effort: export ``fn`` at ``example_args``' avals and persist.

        ``fn`` must be a plain traceable callable (it is jit-wrapped here);
        failures — unexportable programs, serialization errors, IO — are
        counted, never raised.

        Programs containing ``stablehlo.custom_call`` (LAPACK SVD/QR on
        CPU, PRNG kernels) are refused even when jax's own export accepts
        them: on this jax generation a *batched* LAPACK custom call
        deserialized in a fresh process segfaults at execution, so only
        pure-XLA programs (GEMM/gather/reshape cores — the matvec, slice
        and env cores) round-trip.  Refusals count as ``export_failures``;
        the caller re-traces and the persistent compilation cache still
        skips the XLA compile.
        """
        if not _ensure_pytree_serialization():
            with self._lock:
                self.export_failures += 1
            return False
        full_key = (canonical_signature(key), jax_fingerprint(),
                    _aval_fingerprint(example_args))
        path = self._export_path(full_key)
        memo = self._resolve_memo(path)
        if memo is not None and memo[0] == "refused" and memo[1] == full_key:
            # a prior process already proved this core unexportable — the
            # tombstone spares every later process the export + module scan
            with self._lock:
                self.export_failures += 1
            return False
        try:
            import jax
            from jax import export as jax_export

            exported = jax_export.export(jax.jit(fn))(*example_args)
            if "stablehlo.custom_call" in exported.mlir_module():
                entry = {
                    "version": PERSIST_VERSION,
                    "key": full_key,
                    "refused": "custom_call",
                }
                with contextlib.suppress(Exception):
                    _atomic_write_bytes(
                        path,
                        pickle.dumps(entry, protocol=pickle.HIGHEST_PROTOCOL),
                    )
                self._memo[path] = ("refused", full_key, None)
                raise ValueError("custom_call programs do not round-trip")
            entry = {
                "version": PERSIST_VERSION,
                "key": full_key,
                "data": bytes(exported.serialize()),
            }
            _atomic_write_bytes(
                path,
                pickle.dumps(entry, protocol=pickle.HIGHEST_PROTOCOL),
            )
        except Exception:
            with self._lock:
                self.export_failures += 1
            return False
        with self._lock:
            self.export_saves += 1
        return True

    # ------------------------------------------------------------- prefetch
    def prefetch_exports(
        self, *, compile: bool = False, max_workers: int = 4,
        block: bool = False,
    ) -> int:
        """Warm the export memo from disk on background threads.

        Walks ``exports/`` and schedules every entry for deserialization —
        and, with ``compile=True``, AOT compilation at the artifact's own
        recorded avals (``Exported.in_avals``) — on a small thread pool.
        ``load_export`` then finds a ready (or in-flight) callable instead
        of paying deserialize + trace + compile inline, so a fresh worker's
        first sweep overlaps artifact loading with actual solving.

        ``compile=True`` is the warmup half of the cold-start contract: the
        AOT compiles populate the persistent compilation cache with the
        *wrapped-module* executables (distinct cache entries from the
        priming run's own programs), which is exactly what a later worker's
        inline first-use compiles hit.  It is NOT the default because a
        cache-cold compile pass takes minutes of background CPU, and the
        pool's worker threads are joined at interpreter shutdown — fine for
        the blocking warmup driver or a long-lived server, a trap for a
        short-lived CLI process.

        Returns the number of artifacts scheduled (0 if the export layer is
        unavailable); ``block=True`` waits for completion — used by warmup,
        where the point is filling caches, not overlapping work.
        """
        d = os.path.join(self.root, "exports")
        try:
            names = sorted(n for n in os.listdir(d) if n.endswith(".pkl"))
        except FileNotFoundError:
            return 0
        if not names or not _ensure_pytree_serialization():
            return 0
        from concurrent.futures import ThreadPoolExecutor

        pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="plan-store-prefetch"
        )
        n = 0
        for name in names:
            path = os.path.join(d, name)
            if path in self._memo:
                continue
            self._memo[path] = pool.submit(
                self._load_export_entry, path, compile
            )
            n += 1
        pool.shutdown(wait=block)
        with self._lock:
            self.export_prefetched += n
        return n

    def _load_export_entry(self, path: str, compile: bool):
        """Read one export entry: ("fn"|"refused", full_key, callable|None).

        Runs on prefetch threads; returns None on any corrupt, stale or
        foreign-environment entry (``load_export`` then falls back to its
        own tolerant disk path for accurate counters).
        """
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
            if (
                not isinstance(entry, dict)
                or entry.get("version") != PERSIST_VERSION
                or not isinstance(entry.get("key"), tuple)
                or entry["key"][1] != jax_fingerprint()
            ):
                return None
            if entry.get("refused"):
                return ("refused", entry["key"], None)
            import jax
            import jax.tree_util as jtu
            from jax import export as jax_export

            exported = jax_export.deserialize(entry["data"])
            fn = jax.jit(exported.call)
            if compile:
                sds = [
                    jax.ShapeDtypeStruct(a.shape, a.dtype)
                    for a in exported.in_avals
                ]
                args, kwargs = jtu.tree_unflatten(exported.in_tree, sds)
                fn = fn.lower(*args, **kwargs).compile()
            return ("fn", entry["key"], fn)
        except Exception:
            return None

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict[str, Any]:
        """Cumulative store counters.

        ``hits``/``misses``/``saves`` are plan-entry loads that verified /
        found nothing / writes; ``corrupt`` counts unreadable or mismatched
        entries and ``stale`` version-gated rejections (both behave as
        misses).  The ``export_*`` family is the same ledger for
        ``jax.export`` artifacts, plus ``export_failures`` for cores that
        could not be exported in the first place (they fall back to a plain
        re-trace).
        """
        with self._lock:
            return {
                "root": self.root,
                "hits": self.hits,
                "misses": self.misses,
                "saves": self.saves,
                "corrupt": self.corrupt,
                "stale": self.stale,
                "export_hits": self.export_hits,
                "export_misses": self.export_misses,
                "export_saves": self.export_saves,
                "export_failures": self.export_failures,
                "export_corrupt": self.export_corrupt,
                "export_prefetched": self.export_prefetched,
            }


# ------------------------------------------------------------- activation
_active_store: Optional[PlanStore] = None


def enable_compilation_cache(path: str) -> None:
    """Point the JAX persistent compilation cache at ``path``.

    Drops the min-entry-size and min-compile-time floors so the many small
    DMRG cores all qualify — without this, jax's defaults (1 second of
    compile time) would skip exactly the executables whose *count* makes
    cold starts slow.  Idempotent; safe to call after jax is initialized.
    """
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", os.path.abspath(path))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


def activate_store(
    store, *, compile_cache: bool = True, prefetch=True
) -> PlanStore:
    """Attach ``store`` (a PlanStore or a path) as the process-wide store.

    Wires it into the three global ``_SignatureLRU`` caches (consulted on
    every miss, written on every build), publishes it to the engines'
    export lookups (``active_store``), and — unless ``compile_cache=False``
    — enables the JAX persistent compilation cache under
    ``store.compile_cache_dir``.  ``prefetch`` (default on) kicks off the
    background export warm-up (``prefetch_exports``) so first-use lookups
    find ready artifacts; ``prefetch="compile"`` additionally AOT-compiles
    each artifact in the background — the long-lived-worker mode
    (``DMRGService``) that lands a warmed-up worker's first sweep within
    ~2x of steady state.  It is a no-op on a store with no exports, and
    ``prefetch=False`` keeps activation fully synchronous (tests asserting
    exact disk-read sequencing).  Returns the (possibly constructed) store.
    """
    global _active_store
    if not isinstance(store, PlanStore):
        store = PlanStore(store)
    _active_store = store
    _plan_mod._ACTIVE_STORE = store
    if compile_cache:
        enable_compilation_cache(store.compile_cache_dir)
    if prefetch:
        store.prefetch_exports(compile=prefetch == "compile")
    return store


def deactivate_store() -> None:
    """Detach the active store (the compilation-cache dir stays configured:
    un-configuring it mid-process would orphan live executables' entries)."""
    global _active_store
    _active_store = None
    _plan_mod._ACTIVE_STORE = None


def active_store() -> Optional[PlanStore]:
    """The process-wide store engines consult for export round-trips."""
    return _active_store


@contextlib.contextmanager
def using_store(store, *, compile_cache: bool = True, prefetch: bool = True):
    """Scoped ``activate_store``: restores the previous store on exit."""
    prev = _active_store
    s = activate_store(store, compile_cache=compile_cache, prefetch=prefetch)
    try:
        yield s
    finally:
        if prev is None:
            deactivate_store()
        else:
            activate_store(prev, compile_cache=False, prefetch=False)


def store_stats() -> Optional[Dict[str, Any]]:
    """``stats()`` of the active store, or None when none is attached
    (the shape ``repro.dist.cache_stats`` folds in)."""
    return None if _active_store is None else _active_store.stats()


def resolve_store(store) -> Optional[PlanStore]:
    """None | path | PlanStore -> Optional[PlanStore] (drivers' arg coercion)."""
    if store is None or isinstance(store, PlanStore):
        return store
    return PlanStore(store)
