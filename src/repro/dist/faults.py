"""Deterministic fault injection + numerical-health errors (DESIGN.md 3.8).

At supercomputer scale (the paper's Blue Waters/Stampede2 runs) and in a
serving deployment, failures are routine: a batched GEMM can produce NaN on
a flaky node, LAPACK's SVD can fail to converge, a worker thread can die
mid-slot.  This module makes those failure modes *first-class, testable
code paths* instead of hoping they never happen:

- A registry of named **fault points** threaded through the pipeline
  (``FAULT_POINTS`` below).  Each point is a one-line hook at the real code
  site: ``fire("decomp.svd_fail")`` returns the armed fault (or ``None``).
  Disarmed, a hook is a single truthiness check of an empty dict — the
  tier-1 bench leg asserts zero retries/degradations so the hooks provably
  cost nothing when off.
- Faults are **deterministic and seedable**: armed with ``after`` (skip the
  first N reaches) and ``count`` (fire at most N times), so a test can kill
  exactly the 3rd env update of a run and nothing else.
- Arming: programmatically (``registry.arm`` / the ``inject`` context
  manager) or via the ``REPRO_FAULTS`` env var, e.g.::

      REPRO_FAULTS="decomp.svd_fail:count=1,serve.slot_latency:value=0.25"

  parsed once at first registry use — works for any entry point (tests,
  example drivers, ``python -m repro.serve``) without code changes.

Fault hooks NEVER fire under jit tracing: a NaN poisoned at trace time
would be baked into a compiled executable cached far beyond the fault's
lifetime.  Call sites that can trace guard with their existing tracing
flags.

The exception types live here too, because the injection points and the
health guards that catch their damage are two halves of one contract:

- ``FaultInjected`` — raised by "raise"-style fault points.
- ``NumericalHealthError`` — raised by the isfinite/convergence guards that
  piggyback on the pipeline's existing one-host-sync points (the Davidson
  Rayleigh-Ritz read, the post-SVD singular-value sync), so health checking
  costs ZERO extra device round-trips.  For stacked batches it carries a
  per-problem boolean mask, which the serving layer uses to fail exactly
  the poisoned request and retry the rest (``serve/service.py``).
"""
from __future__ import annotations

import dataclasses
import math
import os
import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional


class FaultInjected(RuntimeError):
    """An armed fault point fired in "raise" mode.

    ``point`` names the fault point that fired (a ``FAULT_POINTS`` key), so
    recovery layers can report *which* injected failure they absorbed.
    """

    def __init__(self, point: str, message: Optional[str] = None):
        super().__init__(message or f"injected fault at {point!r}")
        self.point = point


class NumericalHealthError(RuntimeError):
    """A health guard at an existing host-sync point saw bad numerics.

    ``stage`` is the pipeline stage that detected the damage ("davidson",
    "svd", ...) — usually downstream of where the damage occurred, since
    checks ride the existing sync points rather than adding new ones.
    ``problems`` is ``None`` for single-problem runs; for stacked batches it
    is a boolean numpy array ``[B]``, True where that problem's values were
    non-finite — healthy problems in the same batch are NOT flagged, which
    is what lets the serving layer isolate the poisoned request.
    """

    def __init__(self, message: str, stage: str = "", problems=None):
        super().__init__(message)
        self.stage = stage
        self.problems = problems


#: Every named injection point, with where its hook lives.  Arming an
#: unknown name raises immediately (a typo would otherwise silently never
#: fire and the test would pass vacuously).
FAULT_POINTS: Dict[str, str] = {
    # NaN-poison one bucket output of a batched-GEMM contraction
    # (dist/batch.py execute_batched; skipped under tracing).
    "batch.gemm_nan": "dist/batch.py:execute_batched",
    # Forced failure of the planned batched jnp.linalg.svd core, standing in
    # for LAPACK *gesdd non-convergence (dist/decomp.py svd_split, and the
    # stacked svd_split_multi in serve/multicore.py).
    "decomp.svd_fail": "dist/decomp.py:DecompositionEngine.svd_split",
    # Exception out of the fused environment-update core
    # (dist/envcore.py EnvironmentEngine._update).
    "env.exception": "dist/envcore.py:EnvironmentEngine._update",
    # Force a Davidson solve to report non-convergence: the residual break
    # is suppressed, the solve runs its full budget and returns
    # converged=False (core/davidson.py).
    "davidson.no_converge": "core/davidson.py:davidson",
    # Kill the sweep loop after a site update — simulates a mid-sweep crash
    # for checkpoint/resume tests (core/sweep.py DMRGEngine.sweep).
    "sweep.kill": "core/sweep.py:DMRGEngine.sweep",
    # Crash the serving worker thread between slots (outside the per-slot
    # recovery), exercising the watchdog restart (serve/service.py).
    "serve.worker_crash": "serve/service.py:_worker_loop",
    # Artificial latency added to one slot solve (``value`` = seconds).
    "serve.slot_latency": "serve/service.py:_run_slot",
    # NaN-poison the MPO of one request in a slot before solving
    # (``problem`` = the request id, so the poison follows the request
    # through bisection retries), exercising per-problem health masks and
    # slot bisection (serve/service.py).
    "serve.poison_request": "serve/service.py:_run_slot",
}


@dataclasses.dataclass
class ArmedFault:
    """One armed injection: deterministic fire window + payload knobs."""

    point: str
    after: int = 0          # skip the first ``after`` reaches
    count: float = 1        # then fire this many times (math.inf = forever)
    value: float = 0.0      # payload: latency seconds, poison value, ...
    problem: int = 0        # batch position, for per-problem faults
    fired: int = 0          # times this fault actually fired
    seen: int = 0           # times the hook was reached while armed


class FaultRegistry:
    """Thread-safe registry of armed faults; the module ships one instance.

    The fast path is ``fire()`` on an empty registry: a single truthiness
    check of ``self._armed`` with no lock (reading a dict's emptiness is
    atomic under the GIL, and arming is rare + test-only), so production
    code pays nothing for carrying the hooks.
    """

    def __init__(self):
        self._armed: Dict[str, ArmedFault] = {}
        self._lock = threading.Lock()
        self._fired_total: Dict[str, int] = {}
        self._env_parsed = False

    # ------------------------------------------------------------------ arm
    def arm(
        self,
        point: str,
        *,
        after: int = 0,
        count: float = 1,
        value: float = 0.0,
        problem: int = 0,
    ) -> ArmedFault:
        if point not in FAULT_POINTS:
            raise KeyError(
                f"unknown fault point {point!r}; known: {sorted(FAULT_POINTS)}"
            )
        f = ArmedFault(point, after=after, count=count, value=value,
                       problem=problem)
        with self._lock:
            self._armed[point] = f
        return f

    def disarm(self, point: str) -> None:
        with self._lock:
            self._armed.pop(point, None)

    def clear(self) -> None:
        with self._lock:
            self._armed.clear()

    # ----------------------------------------------------------------- fire
    def fire(self, point: str) -> Optional[ArmedFault]:
        """The hook call sites use: None when disarmed / outside the window.

        Deterministic: the ``after``/``count`` window is consumed in hook
        reach order, which the single-threaded sweep and the worker's
        slot loop make reproducible.
        """
        if not self._armed:  # fast path: nothing armed, no lock
            return None
        with self._lock:
            f = self._armed.get(point)
            if f is None:
                return None
            f.seen += 1
            if f.seen <= f.after:
                return None
            if f.fired >= f.count:
                return None
            f.fired += 1
            self._fired_total[point] = self._fired_total.get(point, 0) + 1
            return f

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict:
        with self._lock:
            return {
                "armed": sorted(self._armed),
                "fired": dict(self._fired_total),
            }

    # ---------------------------------------------------------------- env
    def arm_from_env(self, spec: Optional[str] = None) -> None:
        """Arm from a ``REPRO_FAULTS``-style spec string.

        Grammar: comma-separated points, each optionally followed by
        colon-separated ``key=value`` knobs (keys: after, count, value,
        problem; ``count=inf`` fires forever)::

            decomp.svd_fail:count=1:after=2,serve.slot_latency:value=0.25
        """
        spec = os.environ.get("REPRO_FAULTS", "") if spec is None else spec
        for part in filter(None, (p.strip() for p in spec.split(","))):
            name, *kvs = part.split(":")
            kw: Dict[str, float] = {}
            for kv in kvs:
                k, _, v = kv.partition("=")
                if k not in ("after", "count", "value", "problem"):
                    raise ValueError(
                        f"bad REPRO_FAULTS knob {kv!r} in {part!r}"
                    )
                kw[k] = math.inf if v == "inf" else float(v)
            self.arm(
                name,
                after=int(kw.get("after", 0)),
                count=kw.get("count", 1),
                value=kw.get("value", 0.0),
                problem=int(kw.get("problem", 0)),
            )


#: The process-wide registry every hook consults.
registry = FaultRegistry()


def fire(point: str) -> Optional[ArmedFault]:
    """Module-level hook shim (``faults.fire("...")`` at each call site)."""
    return registry.fire(point)


@contextmanager
def inject(point: str, **kw) -> Iterator[ArmedFault]:
    """Arm one fault for the duration of a ``with`` block, then disarm.

    The yielded ``ArmedFault`` exposes ``fired`` so tests can assert the
    fault actually triggered (a hook that silently moved would otherwise
    make the test pass without injecting anything).
    """
    f = registry.arm(point, **kw)
    try:
        yield f
    finally:
        registry.disarm(point)


# Arm anything requested through the environment once, at import: import
# order guarantees this runs before any hook can fire, and an empty/unset
# REPRO_FAULTS is a no-op.
if os.environ.get("REPRO_FAULTS"):
    registry.arm_from_env()
