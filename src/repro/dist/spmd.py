"""True SPMD execution of the bucketed batched GEMMs via ``shard_map``.

This is the distributed-compute half the paper actually claims: instead of
gathering every block to host before computing (the ``BlockShardPolicy``
"storage" fallback), each shape bucket's stacked batched GEMM runs as ONE
SPMD program over the 2-D ("row", "col") device mesh, with collectives
replacing the host gather.

Mesh-axis mapping (per bucket GEMM ``lhs[P,M,K] @ rhs[P,K,N] -> out[O,M,N]``):

- ``P`` (the stacked block-pair axis) is sharded over the **"row"** mesh
  axis — each row shard owns a slice of the pairs and segment-sums its
  partial products locally, so the cross-shard reduction is ONE ``psum``
  over "row" per bucket (the paper's reduction over the processor rows
  that co-own a block's contributions).
- ``N`` (the output block columns) is sharded over the **"col"** mesh axis —
  each col shard computes its column slice, rejoined by ONE tiled
  ``all_gather`` over "col" per bucket.
- ``M``, ``K`` and the output-slot axis ``O`` are unsharded (they ride along
  replicated inside each shard).

Divisibility never forces the storage fallback: ``P`` is zero-padded up to a
multiple of the "row" size (padded pairs carry zero operands and point at
slot 0 — exactly zero contribution) and ``N`` up to a multiple of the "col"
size (the zero columns are sliced off after the gather), so any bucket runs
on any mesh.  Only when the padding would inflate the work past
``PAD_OVERHEAD_LIMIT`` does a call fall back to the plain replicated
segment-sum GEMM (no collectives; counted in ``stats()["fallback_calls"]``).

Equality guarantee: the SPMD bucket GEMM computes the same sum as the
single-device ``block_sparse_matmul`` reference with the per-pair products
reduced in a different association (local segment-sum per row shard, then
``psum``), so outputs agree to floating-point reassociation error — <=1e-12
on random f64 buckets (tests/test_spmd.py) and DMRG energies match the list
backend to <1e-10 at every device count in {1, 2, 4, 8}.

Host-sync count: zero.  Every function here returns device arrays without
blocking; inputs are uploaded once (device-resident replicated placement by
``BlockShardPolicy(mode="spmd")``) and outputs come back fully replicated on
the mesh, so downstream eager block math stays collective-free and the CPU
fake-device runtime cannot deadlock.  The only host syncs in an SPMD sweep
are the ones the sweep always had: the Davidson Rayleigh-Ritz read per
iteration and the one truncation sync per SVD split.

``spmd_env_core_body`` assembles the fused three-contraction environment
update (dist/envcore.py) from the same SPMD bucket GEMMs, so the env stage
partitions over the identical mesh axes as the matvec stage.

Compile unit: the outer fused matvec / env core, with the per-bucket
shard_map programs inlined.  Inlining shard_map under an enclosing
``jax.jit`` is safe here ONLY because the bucket programs keep *replicated
boundaries* (in/out specs all ``P()``, shards slice their own work chunk
inside the body — see ``_build_spmd_gemm``): sharded in_specs would make
XLA's partitioner insert layout transitions at the shard_map boundary,
which cost a reshard per call and, inside an enclosing jit, trigger its
"Involuntary full rematerialization" path that *corrupts values* (a 16x
inflation was observed on a (2, 4) CPU fake-device mesh).  With replicated
boundaries the glue between buckets fuses into the outer program and the
steady-state sweep runs at batched-backend speed plus one psum + one tiled
all_gather per bucket.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .plan import EnvironmentPlan

# padding a bucket past this work-inflation factor is slower than just
# computing it replicated; such calls take the collective-free fallback
PAD_OVERHEAD_LIMIT = 4.0

# ledger, reported by ``stats()``; see its docstring for counter semantics
_counters = {
    "gemm_calls": 0,
    "fallback_calls": 0,
    "psum_traced": 0,
    "all_gather_traced": 0,
}

# jitted SPMD executables keyed by (mesh, P, M, K, N, O): one compile per
# bucket shape per mesh, shared across plans, sites, sweeps and engines —
# the same executable-reuse story as kernels/block_gemm
_GEMM_CACHE: Dict = {}


def stats() -> Dict:
    """SPMD collective-execution counters (cumulative, process-wide).

    - ``gemm_calls``: Python-level entries into the SPMD bucket GEMM.  Under
      an outer jit (the compiled matvec / env core) these count trace-time
      calls, like the engine's ``backend_counts`` — compiled replays bypass
      Python.
    - ``fallback_calls``: of those, how many took the replicated no-collective
      fallback because padding would inflate work > ``PAD_OVERHEAD_LIMIT``.
    - ``psum_traced`` / ``all_gather_traced``: collectives *traced* into
      compiled SPMD programs (one each per unique bucket shape per mesh).
      Executed-collective counts per replay are ``2 * (gemm_calls -
      fallback_calls)`` for the structures those calls traced.
    - ``unique_programs``: distinct compiled SPMD executables alive.
    """
    return dict(_counters, unique_programs=len(_GEMM_CACHE))


def reset_stats() -> None:
    for k in _counters:
        _counters[k] = 0


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _build_spmd_gemm(mesh: Mesh, row_axis: str, col_axis: str,
                     p: int, m: int, k: int, n: int, num_out: int):
    """Jitted SPMD program for one bucket shape on one mesh.

    Replicated-boundary design: in_specs and out_specs are all ``P()`` —
    every device receives the full (replicated) operands and each shard
    *slices its own work chunk* inside the body via ``axis_index`` (pairs
    by "row" rank, output columns by "col" rank).  The alternative —
    sharded in_specs like ``P(row, None, col)`` — makes XLA's partitioner
    insert replicated->sharded layout transitions at the shard_map
    boundary; on CPU meshes those transitions both cost a reshard per call
    and, under an enclosing jit, trigger the partitioner's "Involuntary
    full rematerialization" path which *corrupts values* (16x inflation
    observed on a (2, 4) mesh).  With replicated boundaries there is
    nothing to reshard: the program is safe to inline into an outer jitted
    matvec or env core, and the only cross-device traffic is the one psum
    + one tiled all_gather per bucket.
    """
    rows = int(mesh.shape[row_axis])
    cols = int(mesh.shape[col_axis])
    pp = _ceil_to(p, rows)
    np_ = _ceil_to(n, cols)
    p_chunk = pp // rows
    n_chunk = np_ // cols

    def body(lhs, rhs, oi):
        _counters["psum_traced"] += 1
        _counters["all_gather_traced"] += 1
        r = jax.lax.axis_index(row_axis)
        c = jax.lax.axis_index(col_axis)
        lhs_loc = jax.lax.dynamic_slice_in_dim(lhs, r * p_chunk, p_chunk, 0)
        rhs_loc = jax.lax.dynamic_slice_in_dim(rhs, r * p_chunk, p_chunk, 0)
        rhs_loc = jax.lax.dynamic_slice_in_dim(rhs_loc, c * n_chunk, n_chunk, 2)
        oi_loc = jax.lax.dynamic_slice_in_dim(oi, r * p_chunk, p_chunk, 0)
        part = jax.ops.segment_sum(
            jnp.einsum("pmk,pkn->pmn", lhs_loc, rhs_loc),
            oi_loc,
            num_segments=num_out,
        )
        part = jax.lax.psum(part, row_axis)
        return jax.lax.all_gather(part, col_axis, axis=2, tiled=True)

    # the psum + tiled all_gather leave the output replicated, but shard_map
    # cannot infer that statically -> check_rep=False; equality is pinned by
    # tests/test_spmd.py instead
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(), P()),
        out_specs=P(),
        check_rep=False,
    )

    def fn(lhs, rhs, oi):
        # zero-padded pairs point at slot 0 with zero operands (exact); the
        # padded output columns are sliced off after the gather (exact)
        if pp != p:
            lhs = jnp.pad(lhs, ((0, pp - p), (0, 0), (0, 0)))
            rhs = jnp.pad(rhs, ((0, pp - p), (0, 0), (0, 0)))
            oi = jnp.pad(jnp.asarray(oi), (0, pp - p))
        if np_ != n:
            rhs = jnp.pad(rhs, ((0, 0), (0, 0), (0, np_ - n)))
        out = mapped(lhs, rhs, jnp.asarray(oi))
        return out[:, :, :n] if np_ != n else out

    return jax.jit(fn)


# replicated fallback: same semantics, no collectives — used when padding
# would inflate the bucket's work past PAD_OVERHEAD_LIMIT
@functools.partial(jax.jit, static_argnames=("num_out",))
def _ref_gemm(lhs, rhs, oi, *, num_out):
    return jax.ops.segment_sum(
        jnp.einsum("pmk,pkn->pmn", lhs, rhs), oi, num_segments=num_out
    )


def spmd_bucket_gemm(
    lhs, rhs, oi, num_out: int, *, mesh: Mesh,
    row_axis: str = "row", col_axis: str = "col",
    pad_overhead_limit: float = PAD_OVERHEAD_LIMIT,
):
    """``out[o] = sum_{p: oi[p]=o} lhs[p] @ rhs[p]`` as one SPMD program.

    Drop-in for ``kernels.block_gemm.ops.block_sparse_matmul`` (same
    contract), executed under ``shard_map`` over ``mesh`` with the pair axis
    on ``row_axis`` and the output columns on ``col_axis``; the result is
    fully replicated on the mesh.  See the module docstring for the
    mesh-axis mapping, padding rules and equality guarantee.
    """
    p, m, k = lhs.shape
    n = rhs.shape[2]
    _counters["gemm_calls"] += 1
    rows = int(mesh.shape[row_axis])
    cols = int(mesh.shape[col_axis])
    overhead = (_ceil_to(p, rows) * _ceil_to(n, cols)) / max(p * n, 1)
    if overhead > pad_overhead_limit:
        _counters["fallback_calls"] += 1
        return _ref_gemm(lhs, rhs, jnp.asarray(oi), num_out=num_out)
    key = (mesh, row_axis, col_axis, p, m, k, n, num_out)
    fn = _GEMM_CACHE.get(key)
    if fn is None:
        fn = _build_spmd_gemm(mesh, row_axis, col_axis, p, m, k, n, num_out)
        _GEMM_CACHE[key] = fn
    return fn(lhs, rhs, oi)


def make_spmd_gemm(mesh: Mesh, row_axis: str = "row", col_axis: str = "col"):
    """Bind a mesh: returns a ``gemm_fn(lhs, rhs, oi, num_out)`` for
    ``batch.execute_batched`` / ``batch.execute_batched_blocks``."""

    def gemm_fn(lhs, rhs, oi, num_out):
        return spmd_bucket_gemm(
            lhs, rhs, oi, num_out,
            mesh=mesh, row_axis=row_axis, col_axis=col_axis,
        )

    return gemm_fn


def spmd_env_core_body(plan: EnvironmentPlan, mesh: Mesh):
    """The fused env update with every contraction on the SPMD bucket GEMM.

    Same structure (and accumulation-order caveat: <=1e-12 reassociation
    instead of the exact list order) as ``envcore.env_core_body``; the
    three chained contractions run through ``execute_batched_blocks`` with
    the SPMD gemm, so intermediates never leave the mesh and the traced
    program's only cross-device traffic is the per-bucket psum/all_gather
    pairs.  Never exported to the plan store — shard_map programs close
    over a live mesh.
    """
    from .batch import execute_batched_blocks, matricize_lhs, matricize_rhs

    p1, p2, p3 = plan.steps
    left = plan.side == "left"
    perm = plan.perm
    gemm = make_spmd_gemm(mesh)

    def _step(p, a_blocks, b_blocks):
        if not p.pairs:
            return {}
        a_mats = matricize_lhs(a_blocks, p.keep_a, p.ax_a)
        b_mats = matricize_rhs(b_blocks, p.keep_b, p.ax_b)
        return execute_batched_blocks(
            p, a_mats, b_mats, mesh=mesh, gemm_fn=gemm
        )

    def body(env_blocks, site_blocks, mpo_blocks):
        e = dict(zip(plan.env_keys, env_blocks))
        t = dict(zip(plan.site_keys, site_blocks))
        w = dict(zip(plan.mpo_keys, mpo_blocks))
        bra = {k: jnp.conj(v) for k, v in t.items()}
        if left:
            x = _step(p1, e, t)
            x = _step(p2, x, w)
            x = _step(p3, bra, x)
        else:
            x = _step(p1, t, e)
            x = _step(p2, x, w)
            x = _step(p3, x, bra)
        return tuple(jnp.transpose(x[k], perm) for k in plan.pre_out_keys)

    return body


def replicate_sharding(mesh: Mesh) -> NamedSharding:
    """The fully-replicated mesh sharding device-resident tensors live in."""
    return NamedSharding(mesh, P())
