"""Block placement on the 2-D processor grid: SPMD vs storage modes.

The paper's key layout decision is to distribute *each* quantum-number block
over the whole processor grid instead of assigning whole blocks to nodes —
block sizes are wildly non-uniform (the largest scales ~ m), so
blocks-to-nodes load-imbalances.  ``BlockShardPolicy`` realizes that over a
2-D ("row", "col") device mesh built by ``make_block_mesh``, in one of two
modes:

- **"spmd"** (the real distributed path, DESIGN.md 3.10): tensors are pinned
  **device-resident** — every block is uploaded ONCE to the fully-replicated
  mesh sharding and never re-materializes on host between sites — and the
  heavy compute (the bucketed batched GEMMs of the matvec and env stages) is
  *work*-sharded by ``dist/spmd.py``: inside each compiled SPMD program the
  stacked pair axis partitions over "row" and the output block columns over
  "col", rejoined by one psum + one tiled all_gather per bucket.  Mesh-axis
  mapping of stored tensor dims: none — storage is replicated (a no-op
  ``place_block`` once resident); the "row"/"col" axes carry bucket work,
  not resident layout.  Host-sync count: zero placements or gathers per
  site after ``_init_envs``.

- **"storage"** (the fallback, kept as the pre-SPMD behavior): blocks are
  *stored* sharded — the block's largest mode divisible by the "row" axis
  size maps to "row", the largest remaining mode divisible by the "col"
  size to "col", everything else replicated (``spec_for``) — but every
  engine operation gathers operands to replicated form first (a
  ``device_put`` reshard: runtime copies, ~2 host-coordinated gathers per
  contraction — a ~7x steady-state overhead on the batched backend at 4
  fake devices that the SPMD mode removes; see ``weak_scaling`` in
  benchmarks/bench_dist.json).
  Required shape on the CPU host-device backend when compute must stay
  eager: eager ops on *sharded* arrays each compile their own collectives,
  and the CPU runtime interleaves collectives from different computations
  across device threads and deadlocks their rendezvous.

- "auto" (default): "storage" on an all-CPU mesh, "spmd" otherwise.  The
  SPMD mode is opt-in on CPU fake-device meshes (``run_dmrg(spmd=True)``)
  because it routes all engine contractions through jitted shard_map
  programs — safe (single-program collectives are ordered) but a behavior
  change "auto" must not spring on existing storage-mode callers.

Equality guarantee: placement never changes values in either mode — the
sharded/replicated sweeps match the single-device sweep to <1e-10 (storage:
energy diff 0 in the 8-fake-device smoke; spmd: <1e-10 at device counts
{1, 2, 4, 8}, tests/test_spmd.py — the SPMD bucket GEMM reassociates the
pair reduction, see ``dist/spmd.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.mesh import make_mesh
from ..tensor.blocksparse import BlockSparseTensor


def _near_square_factors(n: int) -> Tuple[int, int]:
    r = 1
    for d in range(1, int(n**0.5) + 1):
        if n % d == 0:
            r = d
    return r, n // r


def make_block_mesh(
    devices: Optional[Sequence] = None, shape: Optional[Tuple[int, int]] = None
) -> Mesh:
    """2-D ("row", "col") mesh over all (or the given) devices."""
    n = len(devices) if devices is not None else jax.device_count()
    if shape is None:
        shape = _near_square_factors(n)
    assert shape[0] * shape[1] == n, f"mesh shape {shape} != {n} devices"
    return make_mesh(shape, ("row", "col"), devices=devices)


@dataclasses.dataclass
class BlockShardPolicy:
    """Places blocks on the mesh; mode picks the execution style.

    ``mode``:

    - "spmd": device-resident replicated storage + shard_map collective
      compute (``dist/spmd.py``); ``place_block`` uploads a block to the
      mesh once and is a no-op when it is already resident.
    - "storage": sharded storage (``spec_for`` row/col assignment) with
      gather-before-compute in the engines.
    - "auto" (default): "storage" on an all-CPU mesh, "spmd" otherwise.

    See the module docstring for the full dataflow of each mode.
    """

    mesh: Mesh
    row_axis: str = "row"
    col_axis: str = "col"
    mode: str = "auto"

    def __post_init__(self):
        assert self.mode in ("auto", "spmd", "storage")
        if self.mode == "auto":
            all_cpu = all(d.platform == "cpu" for d in self.mesh.devices.flat)
            self.mode = "storage" if all_cpu else "spmd"
        self._device_set = frozenset(self.mesh.devices.flat)

    @property
    def storage_only(self) -> bool:
        return self.mode == "storage"

    def spec_for(self, shape: Tuple[int, ...]) -> P:
        """Storage-mode layout: largest divisible mode -> "row", next ->
        "col", indivisible modes replicated.  (SPMD mode stores replicated
        and ignores this; kept public for layout introspection.)"""
        row_n = int(self.mesh.shape[self.row_axis])
        col_n = int(self.mesh.shape[self.col_axis])
        assign = [None] * len(shape)
        # largest mode divisible by the row-axis size gets the row axis
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        row_at = next((i for i in order if shape[i] % row_n == 0 and row_n > 1), None)
        if row_at is not None:
            assign[row_at] = self.row_axis
        col_at = next(
            (
                i
                for i in order
                if i != row_at and shape[i] % col_n == 0 and col_n > 1
            ),
            None,
        )
        if col_at is not None:
            assign[col_at] = self.col_axis
        return P(*assign)

    def sharding_for(self, shape: Tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(tuple(shape)))

    def place_block(self, block: jax.Array) -> jax.Array:
        if isinstance(block, jax.core.Tracer):  # inside jit: layout is XLA's
            return block
        if self.mode == "spmd":
            return self._mesh_resident(block)
        return jax.device_put(block, self.sharding_for(block.shape))

    def _mesh_resident(self, block: jax.Array) -> jax.Array:
        """Upload once to the replicated mesh sharding; no-op when already
        resident (the steady state: SPMD program outputs come back
        replicated on the same mesh, so sweeps never re-upload)."""
        sh = getattr(block, "sharding", None)
        if (
            sh is not None
            and sh.is_fully_replicated
            and getattr(sh, "device_set", None) == self._device_set
        ):
            return block
        return jax.device_put(block, NamedSharding(self.mesh, P()))

    def place(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Re-place every block of a tensor per the policy (no-op on values)."""
        return BlockSparseTensor(
            t.indices, {k: self.place_block(b) for k, b in t.blocks.items()}, t.charge
        )

    def place_mps(self, tensors):
        return [self.place(t) for t in tensors]

    # --------------------------------------------------------------- gather
    def _replicated_block(self, block: jax.Array) -> jax.Array:
        if isinstance(block, jax.core.Tracer):
            return block
        sh = getattr(block, "sharding", None)
        if sh is not None and sh.is_fully_replicated:
            return block
        return jax.device_put(block, NamedSharding(self.mesh, P()))

    def replicated(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Gather every block to full replication (runtime copy, no XLA
        collectives) so downstream eager math is collective-free.  The
        storage-mode gather; in spmd mode blocks are already replicated
        and this is a no-op."""
        return BlockSparseTensor(
            t.indices,
            {k: self._replicated_block(b) for k, b in t.blocks.items()},
            t.charge,
        )
