"""Block sharding: every block over all processors (paper Fig. 2a).

The paper's key layout decision is to distribute *each* quantum-number block
over the whole processor grid instead of assigning whole blocks to nodes —
block sizes are wildly non-uniform (the largest scales ~ m), so
blocks-to-nodes load-imbalances.  Here each block is a ``jax.Array`` placed
with a ``NamedSharding`` over a 2-D ("row", "col") device mesh built by
``launch/mesh.make_mesh``: the block's largest mode divisible by the "row"
axis size is row-sharded, the largest remaining mode divisible by the "col"
axis size is col-sharded, and everything else — including whole blocks whose
modes are all indivisible, common for the tiny edge sectors — falls back to
replication.  Replication is always correct (jax inserts resharding
collectives as needed), so the policy is purely a performance hint and the
sharded sweep is numerically identical to the single-device sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.mesh import make_mesh
from ..tensor.blocksparse import BlockSparseTensor


def _near_square_factors(n: int) -> Tuple[int, int]:
    r = 1
    for d in range(1, int(n**0.5) + 1):
        if n % d == 0:
            r = d
    return r, n // r


def make_block_mesh(
    devices: Optional[Sequence] = None, shape: Optional[Tuple[int, int]] = None
) -> Mesh:
    """2-D ("row", "col") mesh over all (or the given) devices."""
    n = len(devices) if devices is not None else jax.device_count()
    if shape is None:
        shape = _near_square_factors(n)
    assert shape[0] * shape[1] == n, f"mesh shape {shape} != {n} devices"
    return make_mesh(shape, ("row", "col"), devices=devices)


@dataclasses.dataclass
class BlockShardPolicy:
    """Places each block's modes on mesh axes, replicating when indivisible.

    ``mode`` selects how sharded blocks are *computed* on:

    - "spmd": operands stay sharded through eager ops; XLA partitions each
      GEMM and inserts collectives (the intended layout on TPU/GPU, where the
      runtime orders collectives per device).
    - "storage": blocks are stored sharded on the mesh, but the engine
      gathers operands to replicated form (a device_put reshard — runtime
      copies, no XLA collectives) before computing.  Required on the CPU
      host-device backend: eager ops each compile their own collectives, the
      CPU runtime dispatches computations asynchronously, and collectives
      from different computations (over different device subsets) interleave
      across device threads and deadlock their rendezvous.
    - "auto" (default): "storage" on an all-CPU mesh, "spmd" otherwise.
    """

    mesh: Mesh
    row_axis: str = "row"
    col_axis: str = "col"
    mode: str = "auto"

    def __post_init__(self):
        assert self.mode in ("auto", "spmd", "storage")
        if self.mode == "auto":
            all_cpu = all(d.platform == "cpu" for d in self.mesh.devices.flat)
            self.mode = "storage" if all_cpu else "spmd"

    @property
    def storage_only(self) -> bool:
        return self.mode == "storage"

    def spec_for(self, shape: Tuple[int, ...]) -> P:
        row_n = int(self.mesh.shape[self.row_axis])
        col_n = int(self.mesh.shape[self.col_axis])
        assign = [None] * len(shape)
        # largest mode divisible by the row-axis size gets the row axis
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        row_at = next((i for i in order if shape[i] % row_n == 0 and row_n > 1), None)
        if row_at is not None:
            assign[row_at] = self.row_axis
        col_at = next(
            (
                i
                for i in order
                if i != row_at and shape[i] % col_n == 0 and col_n > 1
            ),
            None,
        )
        if col_at is not None:
            assign[col_at] = self.col_axis
        return P(*assign)

    def sharding_for(self, shape: Tuple[int, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(tuple(shape)))

    def place_block(self, block: jax.Array) -> jax.Array:
        if isinstance(block, jax.core.Tracer):  # inside jit: layout is XLA's
            return block
        return jax.device_put(block, self.sharding_for(block.shape))

    def place(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Re-place every block of a tensor per the policy (no-op on values)."""
        return BlockSparseTensor(
            t.indices, {k: self.place_block(b) for k, b in t.blocks.items()}, t.charge
        )

    def place_mps(self, tensors):
        return [self.place(t) for t in tensors]

    # --------------------------------------------------------------- gather
    def _replicated_block(self, block: jax.Array) -> jax.Array:
        if isinstance(block, jax.core.Tracer):
            return block
        sh = getattr(block, "sharding", None)
        if sh is not None and sh.is_fully_replicated:
            return block
        return jax.device_put(block, NamedSharding(self.mesh, P()))

    def replicated(self, t: BlockSparseTensor) -> BlockSparseTensor:
        """Gather every block to full replication (runtime copy, no XLA
        collectives) so downstream eager math is collective-free."""
        return BlockSparseTensor(
            t.indices,
            {k: self._replicated_block(b) for k, b in t.blocks.items()},
            t.charge,
        )
