"""Decomposition engine: plan-cached, shape-bucketed batched truncated SVD.

The blockwise truncated SVD across a bond (paper Fig. 1e, Sec. IV-A) is the
second cost center of the DMRG pipeline next to contractions — Menczer et
al. (arXiv:2407.07411) show it becomes the scaling bottleneck once the
contractions are batched onto accelerators.  The seed ``svd_split`` rebuilt
each charge-sector matrix with one ``.at[].set()`` dispatch per block, ran
one ``jnp.linalg.svd`` per sector sequentially, and synced the singular
values of every sector to host separately.  This module mirrors the
plan/execute split of the contraction engine for that stage:

1. A ``DecompositionPlan`` (``dist/plan.py``, cached by structural
   signature) precomputes the sector grouping, row/column layouts and a
   gather index table per *shape bucket* — all sectors whose matrices pad to
   the same power-of-two ``(Rp, Cp)`` — from ``Index`` metadata alone.
2. ``DecompositionEngine.svd_split`` executes the plan as ONE jit-compiled
   core per bucketed structure: a single gather assembles each bucket's
   stacked ``[S, Rp, Cp]`` sector matrices straight from the flattened theta
   blocks (no per-block ``.at[].set()``), each bucket runs as one batched
   ``jnp.linalg.svd``, padding singular values are masked to exact zero in
   padded space, and the absorb scaling happens on device.  Only the
   (small) concatenated singular-value vector is synced to host — one sync
   per call instead of one per sector — where the global truncation picks
   the retained bond.
3. For sectors where ``min(R, C)`` far exceeds the requested ``max_bond``, a
   randomized-SVD path (sketch + power iteration, Halko et al. 2011)
   computes only the top ``max_bond + oversample`` triplets; ``method="auto"``
   enables it per bucket through a flop cost model.

Backend-equality guarantee: with the default exact method, the split matches
the seed ``svd_split_unplanned`` to <1e-10 up to the per-singular-vector
sign gauge — the products U·V (and therefore all DMRG energies and reduced
density matrices), the singular values, the retained bond sectors and the
truncation error agree unconditionally; individual U/V blocks may differ by
a column/row sign because LAPACK's sign choice is not specified.  Exact
ties in singular values at the truncation threshold are broken
deterministically by (sector charge order, position), keeping the total
retained bond ≤ ``max_bond`` — the seed path can exceed ``max_bond`` on
exact ties.  The randomized method is approximate by construction and is
never chosen unless explicitly requested ("randomized") or cost-justified
under ``method="auto"``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..tensor.blocksparse import BlockSparseTensor
from ..tensor.qn import IN, Index, OUT, qzero
from . import faults, persist
from .batch import is_tracing as _is_tracing
from .faults import FaultInjected, NumericalHealthError
from .plan import (
    DecompPlanCache,
    DecompositionPlan,
    global_decomp_cache,
    svd_flop_estimate,
)

# per-plan cap on cached compiled cores (the batched-SVD core per
# (absorb, methods, sketch) and one slice core per kept-count tuple): the
# kept counts drift while a run converges, so without a bound every
# truncation pattern ever seen would pin an executable (and the engine that
# compiled it, via the closure) for the life of the globally cached plan.
# FIFO eviction; an evicted core is simply recompiled on next use.
_EXEC_CACHE_MAX = 32


def _cache_exec(plan: DecompositionPlan, key, core):
    plan._exec[key] = core
    while len(plan._exec) > _EXEC_CACHE_MAX:
        plan._exec.pop(next(iter(plan._exec)))


def _randomized_svd(
    mats: jax.Array, sketch: int, power_iters: int, seed: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched randomized range-finder SVD (Halko/Martinsson/Tropp 2011).

    Returns the approximate top-``sketch`` triplets of every stacked matrix:
    project onto a random sketch, orthonormalize, refine with QR-stabilized
    power iterations, then SVD the small projected matrix.  Accuracy decays
    with the singular-value tail beyond the sketch — callers must keep
    ``sketch`` comfortably above the retained bond (the engine uses
    ``max_bond + rsvd_oversample``).
    """
    cp = mats.shape[-1]
    key = jax.random.PRNGKey(seed)
    G = jnp.asarray(
        jax.random.normal(key, (cp, sketch), jnp.float64 if mats.dtype in (jnp.float64, jnp.complex128) else jnp.float32),
        mats.dtype,
    )
    Q, _ = jnp.linalg.qr(mats @ G)                                 # [S, rp, l]
    mats_h = jnp.swapaxes(jnp.conj(mats), -1, -2)
    for _ in range(power_iters):
        Z, _ = jnp.linalg.qr(mats_h @ Q)                           # [S, cp, l]
        Q, _ = jnp.linalg.qr(mats @ Z)
    B = jnp.swapaxes(jnp.conj(Q), -1, -2) @ mats                   # [S, l, cp]
    Ub, s, Vh = jnp.linalg.svd(B, full_matrices=False)
    return Q @ Ub, s, Vh


def _rsvd_flops(rp: int, cp: int, sketch: int, power_iters: int) -> float:
    """Flop estimate for one randomized SVD: sketch + power-iteration GEMMs
    (2·rp·cp·l each), QR factorizations (~2·dim·l²) and the small SVD."""
    gemms = (2.0 + 2.0 * power_iters) * 2.0 * rp * cp * sketch
    qrs = (1.0 + 2.0 * power_iters) * 2.0 * (rp + cp) * sketch**2
    return gemms + qrs + svd_flop_estimate(sketch, cp)


# The traced bodies and the host truncation live at module level (not as
# engine methods) so the multi-problem solver (repro/serve/multicore.py) can
# wrap the *same* code in ``jax.vmap`` and in per-problem host loops —
# per-problem SVD/truncation semantics then cannot diverge from the
# single-problem engine by construction.
def svd_core_body(
    plan: DecompositionPlan,
    absorb: str,
    methods: Tuple[str, ...],
    sketch: int,
    rsvd_power_iters: int = 2,
    rsvd_seed: int = 0,
):
    """Assembly + batched SVD + masking + absorb, one traceable function.

    Input: theta's block arrays in ``plan.block_order``.  Output: per bucket
    ``(U, s, Vh)`` with padding singular values masked to exact zero and the
    absorb scaling applied to U ("left") or Vh ("right"), plus the
    concatenated singular values of all buckets (the only array the caller
    syncs to host).  The gather tables fold into the trace as constants, so
    a compiled executable is keyed purely by the bucketed block structure.
    """

    def body(blocks):
        flat = jnp.pad(jnp.concatenate([b.reshape(-1) for b in blocks]), (0, 1))
        out, s_parts = [], []
        for bi, bucket in enumerate(plan.buckets):
            mats = flat[bucket.gather]
            if methods[bi] == "rsvd":
                U, s, Vh = _randomized_svd(
                    mats, sketch, rsvd_power_iters, rsvd_seed + bi
                )
            else:
                U, s, Vh = jnp.linalg.svd(mats, full_matrices=False)
            # padding rows/cols contribute ~eps junk values; zero them so
            # the host truncation only ever sees the K=min(R,C) real ones
            mask = jnp.arange(s.shape[-1])[None, :] < bucket.k_true[:, None]
            s = jnp.where(mask, s, jnp.zeros((), s.dtype))
            if absorb == "left":
                U = U * s[:, None, :].astype(U.dtype)
            elif absorb == "right":
                Vh = Vh * s[:, :, None].astype(Vh.dtype)
            out.append((U, s, Vh))
            s_parts.append(s.reshape(-1))
        return tuple(out), jnp.concatenate(s_parts)

    return body


def slice_core_body(plan: DecompositionPlan, m_q: Tuple[int, ...]):
    """Slice every retained U column / V row / singular value, traceable.

    ``m_q`` (retained count per sector) is static — it keys the compiled
    executable.  Returns flat tuples of U blocks, V blocks and per-sector
    singular values in plan order, skipping sectors with ``m_q == 0``.
    """

    def body(bucket_out):
        u_out, v_out, s_out = [], [], []
        for si, sec in enumerate(plan.sectors):
            m = m_q[si]
            if m == 0:
                continue
            U, s, Vh = bucket_out[sec.bucket]
            Uq, Vq = U[sec.slot], Vh[sec.slot]
            s_out.append(s[sec.slot, :m])
            for rk, rd, ro in zip(sec.row_keys, sec.rdims, sec.roffs):
                shp = tuple(
                    ix.sector_dim(sk) for ix, sk in zip(plan.row_ix, rk)
                ) + (m,)
                u_out.append(Uq[ro : ro + rd, :m].reshape(shp))
            for ck, cd, co in zip(sec.col_keys, sec.cdims, sec.coffs):
                shp = (m,) + tuple(
                    ix.sector_dim(sk) for ix, sk in zip(plan.col_ix, ck)
                )
                v_out.append(Vq[:m, co : co + cd].reshape(shp))
        return tuple(u_out), tuple(v_out), tuple(s_out)

    return body


def host_truncate(
    plan: DecompositionPlan,
    s_host: np.ndarray,
    k_out,
    max_bond: int,
    cutoff: float,
) -> Tuple[np.ndarray, float]:
    """Global truncation on the host-synced singular values of one problem.

    ``s_host`` is the concatenated (masked) singular-value vector a
    ``svd_core_body`` call produced; ``k_out`` the per-bucket value counts.
    Returns ``(m_q, trunc_err)``: retained count per plan sector (ties broken
    deterministically by (sector, position)) and the tail sum of squares.
    """
    sec_vals: list = [None] * plan.num_sectors
    off = 0
    for b, bucket in enumerate(plan.buckets):
        kb = k_out[b]
        for slot, si in enumerate(bucket.sectors):
            avail = min(plan.sectors[si].K, kb)
            sec_vals[si] = s_host[off + slot * kb : off + slot * kb + avail]
        off += len(bucket.sectors) * kb

    vals = np.concatenate(sec_vals)
    sec_id = np.concatenate(
        [np.full(len(v), si, np.int64) for si, v in enumerate(sec_vals)]
    )
    pos_id = np.concatenate([np.arange(len(v)) for v in sec_vals])
    order = np.lexsort((pos_id, sec_id, -vals))
    smax = float(vals[order[0]]) if len(order) else 1.0
    n_keep = int(min(int(max_bond), int(np.sum(vals > cutoff * smax))))
    n_keep = max(n_keep, 1)
    kept = order[:n_keep]
    m_q = np.zeros(plan.num_sectors, np.int64)
    np.add.at(m_q, sec_id[kept], 1)
    # direct tail sum, like the seed: exactly 0.0 when nothing is truncated
    # (a total-minus-kept difference would leave ~eps noise of either sign
    # from summing the same multiset in two orders)
    trunc_err = float(np.sum(vals[order[n_keep:]] ** 2))
    return m_q, trunc_err


class DecompositionEngine:
    """Executes cached DecompositionPlans as bucketed batched SVDs.

    Parameters
    ----------
    cache: ``DecompPlanCache`` (defaults to the global one, shared with any
        other engine — plans and their compiled cores are reused).
    method: "svd" (exact batched SVD, the default and the only path with the
        <1e-10 seed-equality guarantee), "randomized" (randomized SVD on
        every bucket where the sketch is smaller than the full rank), or
        "auto" (per-bucket flop cost model chooses between the two).
    jit: compile the assembly+SVD core once per bucketed structure (default);
        ``False`` runs it eagerly, for debugging.
    rsvd_oversample / rsvd_power_iters / rsvd_seed: randomized-path knobs —
        sketch size is ``max_bond + rsvd_oversample``, power iterations
        sharpen the spectrum estimate, and the seed fixes the sketch matrix
        so repeated calls are deterministic.

    ``stats()`` reports cumulative counters; see its docstring for units.
    """

    def __init__(
        self,
        cache: Optional[DecompPlanCache] = None,
        method: str = "svd",
        *,
        jit: bool = True,
        rsvd_oversample: int = 8,
        rsvd_power_iters: int = 2,
        rsvd_min_gain: float = 1.0,
        rsvd_seed: int = 0,
    ):
        assert method in ("svd", "randomized", "auto")
        self.cache = cache if cache is not None else global_decomp_cache
        self.method = method
        self.jit = jit
        self.rsvd_oversample = rsvd_oversample
        self.rsvd_power_iters = rsvd_power_iters
        self.rsvd_min_gain = rsvd_min_gain
        self.rsvd_seed = rsvd_seed
        self.svd_calls = 0
        self.svd_flops = 0.0
        self.svd_seconds = 0.0
        self.jit_retraces = 0
        self.sectors_processed = 0
        self.buckets_processed = 0
        self.rsvd_buckets = 0
        # degradation ladder ledger (DESIGN.md 3.8): ``retries`` counts
        # splits whose first attempt failed; ``degradations`` counts which
        # ladder rung recovered them.  Both stay zero on a healthy run —
        # the bench gate asserts it.
        self.retries = 0
        self.degradations = {"svd_exact": 0, "svd_unplanned": 0}

    # ------------------------------------------------------------ cost model
    def _bucket_methods(
        self, plan: DecompositionPlan, max_bond: int
    ) -> Tuple[Tuple[str, ...], int]:
        """Per-bucket "svd"/"rsvd" choice and the sketch size.

        The randomized path is meaningful only when the sketch is strictly
        below the bucket's full rank ``min(Rp, Cp)``; under "auto" it must
        also win the flop comparison by ``rsvd_min_gain``x.
        """
        sketch = max_bond + self.rsvd_oversample
        if self.method == "svd":
            return ("svd",) * plan.num_buckets, sketch
        methods = []
        for b in plan.buckets:
            if sketch >= b.kp:
                methods.append("svd")
            elif self.method == "randomized":
                methods.append("rsvd")
            else:  # auto: flop cost model
                full = svd_flop_estimate(b.rp, b.cp)
                rand = _rsvd_flops(b.rp, b.cp, sketch, self.rsvd_power_iters)
                methods.append("rsvd" if rand * self.rsvd_min_gain < full else "svd")
        return tuple(methods), sketch

    def _call_flops(
        self, plan: DecompositionPlan, methods: Tuple[str, ...], sketch: int
    ) -> float:
        total = 0.0
        for b, m in zip(plan.buckets, methods):
            per = (
                _rsvd_flops(b.rp, b.cp, sketch, self.rsvd_power_iters)
                if m == "rsvd"
                else svd_flop_estimate(b.rp, b.cp)
            )
            total += len(b.sectors) * per
        return total

    # ------------------------------------------------------------- jit core
    def _build_core(
        self, plan: DecompositionPlan, absorb: str, methods: Tuple[str, ...], sketch: int
    ):
        """Compile (or wrap eagerly) the shared ``svd_core_body``.

        One compiled executable per bucketed structure — the same
        compile-once trick as ``pad_block_sparse``.
        """
        engine = self
        body = svd_core_body(
            plan, absorb, methods, sketch, self.rsvd_power_iters, self.rsvd_seed
        )
        if not self.jit:
            return body

        def traced(blocks):
            engine.jit_retraces += 1  # body runs only when jax (re)traces
            return body(blocks)

        return jax.jit(traced)

    def _build_slice_core(self, plan: DecompositionPlan, m_q: Tuple[int, ...]):
        """Compile (or wrap eagerly) the shared ``slice_core_body``.

        The retained counts ``m_q`` are static (they key the compiled
        executable): during convergence they drift and retrace like the
        bucketed matvec, but at structural steady state the truncation
        pattern stabilizes and the whole output assembly — dozens of block
        slices per split — replays as one compiled program instead of one
        dispatch per block.
        """
        engine = self
        body = slice_core_body(plan, m_q)
        if not self.jit:
            return body

        def traced(bucket_out):
            engine.jit_retraces += 1
            return body(bucket_out)

        return jax.jit(traced)

    # ----------------------------------------------------------------- entry
    def svd_split(
        self,
        theta: BlockSparseTensor,
        n_row_modes: int,
        max_bond: int,
        cutoff: float = 1e-12,
        absorb: str = "right",
    ):
        """Planned blockwise truncated SVD; drop-in for the seed signature.

        Returns ``(U, V, svals_by_sector, trunc_err)`` exactly like
        ``tensor.blocksparse.svd_split_unplanned``; see the module docstring
        for the equality guarantee and tie-break semantics.  ``trunc_err``
        (a host float) is the sum of the squared discarded singular values —
        equal to the squared Frobenius reconstruction error
        ``||theta - U·V||²`` when ``absorb`` is "left" or "right".

        Robustness (DESIGN.md 3.8): a failed attempt — an exception out of
        the batched SVD core (LAPACK non-convergence, an injected
        ``decomp.svd_fail``) or non-finite singular values at the host sync
        — retries down the documented ladder: randomized → exact batched SVD
        → the seed per-sector loop (``svd_split_unplanned``).  Each rung is
        counted in ``stats()['retries']`` / ``['degradations']``; if the
        final rung still yields non-finite values the input itself is
        poisoned and ``NumericalHealthError`` propagates to the caller.
        """
        if _is_tracing(theta):
            raise TypeError(
                "svd_split needs concrete blocks: the global truncation syncs "
                "singular values to host, so it cannot run under jit tracing"
            )
        t0 = time.perf_counter()
        try:
            plan = self.cache.get(theta, n_row_modes)
            methods, sketch = self._bucket_methods(plan, int(max_bond))
            try:
                f = faults.fire("decomp.svd_fail")
                if f is not None:
                    raise FaultInjected("decomp.svd_fail",
                                        "batched SVD did not converge")
                return self._execute_planned(
                    plan, theta, max_bond, cutoff, absorb, methods, sketch
                )
            except Exception:
                self.retries += 1
                if "rsvd" in methods:
                    # ladder rung 1: drop the randomized sketch, retry exact
                    self.degradations["svd_exact"] += 1
                    try:
                        return self._execute_planned(
                            plan, theta, max_bond, cutoff, absorb,
                            ("svd",) * plan.num_buckets, sketch,
                        )
                    except Exception:
                        pass
                # ladder rung 2 (final): the seed per-sector loop
                self.degradations["svd_unplanned"] += 1
                from ..tensor.blocksparse import svd_split_unplanned

                U_t, V_t, svals, trunc_err = svd_split_unplanned(
                    theta, n_row_modes, max_bond, cutoff=cutoff, absorb=absorb
                )
                s_all = np.concatenate(
                    [np.asarray(jax.device_get(s)).ravel()
                     for s in svals.values()]
                ) if svals else np.zeros(0)
                if not np.isfinite(s_all).all():
                    raise NumericalHealthError(
                        "non-finite singular values even on the seed path: "
                        "the decomposition input is poisoned",
                        stage="svd",
                    )
                return U_t, V_t, svals, trunc_err
        finally:
            self.svd_seconds += time.perf_counter() - t0

    def _execute_planned(
        self, plan, theta, max_bond, cutoff, absorb, methods, sketch
    ):
        """One planned attempt: core exec + the single sync + slicing."""
        key = (
            absorb if absorb in ("left", "right") else "none",
            methods,
            sketch if "rsvd" in methods else 0,
            self.jit,
            self.rsvd_power_iters,
            self.rsvd_seed,
        )
        blocks_in = tuple(theta.blocks[k] for k in plan.block_order)
        core = plan._exec.get(key)
        if core is None:
            # export round-trip (dist/persist.py): a primed store replays the
            # core's StableHLO instead of re-tracing the Python body; a cold
            # run with a store attached exports what it builds (best-effort —
            # any failure just re-traces).  Only the jitted path exports.
            store = persist.active_store() if self.jit else None
            ekey = ("svd_core", plan.signature, key)
            if store is not None:
                core = store.load_export(ekey, (blocks_in,))
            if core is None:
                core = self._build_core(plan, key[0], methods, sketch)
                if store is not None:
                    store.save_export(
                        ekey,
                        svd_core_body(
                            plan, key[0], methods, sketch,
                            self.rsvd_power_iters, self.rsvd_seed,
                        ),
                        (blocks_in,),
                    )
            _cache_exec(plan, key, core)
        bucket_out, s_cat = core(blocks_in)

        self.svd_calls += 1
        self.svd_flops += self._call_flops(plan, methods, sketch)
        self.sectors_processed += plan.num_sectors
        self.buckets_processed += plan.num_buckets
        self.rsvd_buckets += sum(1 for m in methods if m == "rsvd")

        # ---- the one host sync: all singular values, already masked.  The
        # numerical-health guard rides this existing sync (zero extra device
        # round-trips): non-finite values here mean the SVD input or the
        # decomposition itself went bad, and must not reach the MPS.
        s_host = np.asarray(jax.device_get(s_cat))
        if not np.isfinite(s_host).all():
            raise NumericalHealthError(
                "non-finite singular values at the truncation sync",
                stage="svd",
            )
        k_out = [int(out[1].shape[-1]) for out in bucket_out]
        # global truncation, deterministic tie-break (sector, position)
        m_q, trunc_err = host_truncate(plan, s_host, k_out, max_bond, cutoff)

        # ---- slice the retained columns/rows into output blocks: one
        # compiled call keyed by the kept-count tuple (stable at steady state)
        m_tuple = tuple(int(x) for x in m_q)
        slice_key = ("slice", key, m_tuple)
        slice_core = plan._exec.get(slice_key)
        if slice_core is None:
            store = persist.active_store() if self.jit else None
            ekey = ("svd_slice", plan.signature, key, m_tuple)
            if store is not None:
                slice_core = store.load_export(ekey, (bucket_out,))
            if slice_core is None:
                slice_core = self._build_slice_core(plan, m_tuple)
                if store is not None:
                    store.save_export(
                        ekey, slice_core_body(plan, m_tuple), (bucket_out,)
                    )
            _cache_exec(plan, slice_key, slice_core)
        u_flat, v_flat, s_flat = slice_core(bucket_out)

        new_sectors, u_blocks, v_blocks, svals = [], {}, {}, {}
        ui = vi = si_out = 0
        for si, sec in enumerate(plan.sectors):
            m = m_tuple[si]
            if m == 0:
                continue
            svals[sec.q] = s_flat[si_out]
            si_out += 1
            new_sectors.append((sec.q, m))
            for rk in sec.row_keys:
                u_blocks[(sec.q, rk)] = u_flat[ui]
                ui += 1
            for ck in sec.col_keys:
                v_blocks[(sec.q, ck)] = v_flat[vi]
                vi += 1

        bond_u = Index(tuple(new_sectors), IN, "bond")
        bond_v = Index(tuple(new_sectors), OUT, "bond")
        sector_index = {q: i for i, (q, _) in enumerate(new_sectors)}
        U_t = BlockSparseTensor(
            list(plan.row_ix) + [bond_u],
            {rk + (sector_index[q],): b for (q, rk), b in u_blocks.items()},
            qzero(theta.indices[0].nq),
        )
        V_t = BlockSparseTensor(
            [bond_v] + list(plan.col_ix),
            {(sector_index[q],) + ck: b for (q, ck), b in v_blocks.items()},
            theta.charge,
        )
        return U_t, V_t, svals, trunc_err

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict:
        """Cumulative decomposition-stage counters.

        - ``plan_cache``: hits/misses/size of the DecompPlanCache.
        - ``svd_calls``: number of ``svd_split`` executions.
        - ``svd_flops``: estimated flops of the executed decompositions
          (LAPACK-gesdd-style counts for exact buckets, sketch+power-GEMM
          counts for randomized ones) — a cost-model estimate, not a
          hardware counter.
        - ``svd_seconds``: host wall-clock per call, *including* the
          singular-value device sync — unlike the contraction engine's
          ``backend_seconds`` this reflects actual device compute, because
          the sync blocks on the batched SVDs.
        - ``jit_retraces``: times the compiled cores (batched-SVD core and
          output-slice core) were (re)traced; at structural steady state
          this stops growing (compile-once).  Cores are cached on the plan
          and shared across engines using the same cache, so a trace is
          attributed to the engine that first compiled it.
        - ``sectors`` / ``buckets``: cumulative charge sectors decomposed
          and shape buckets executed (buckets ≤ sectors; the gap is the
          batching win).
        - ``rsvd_buckets``: buckets routed to the randomized path.
        - ``retries`` / ``degradations``: failed first attempts and the
          ladder rung that recovered them ("svd_exact": randomized dropped
          for exact, "svd_unplanned": fell back to the seed per-sector
          loop).  Zero on a healthy run (the bench gate asserts this).
        """
        return {
            "plan_cache": self.cache.stats(),
            "svd_calls": self.svd_calls,
            "svd_flops": self.svd_flops,
            "svd_seconds": self.svd_seconds,
            "jit_retraces": self.jit_retraces,
            "sectors": self.sectors_processed,
            "buckets": self.buckets_processed,
            "rsvd_buckets": self.rsvd_buckets,
            "retries": self.retries,
            "degradations": dict(self.degradations),
        }


# Default engine behind ``tensor.blocksparse.svd_split`` (module-level so the
# plan cache and compiled cores persist across calls); sweep-owned
# ContractionEngines carry their own DecompositionEngine for per-run stats.
default_decomp_engine = DecompositionEngine()


def svd_split_planned(
    theta: BlockSparseTensor,
    n_row_modes: int,
    max_bond: int,
    cutoff: float = 1e-12,
    absorb: str = "right",
    engine: Optional[DecompositionEngine] = None,
):
    """Functional entry to the planned split (module docstring has the
    guarantees); uses the shared ``default_decomp_engine`` unless given one."""
    return (engine or default_decomp_engine).svd_split(
        theta, n_row_modes, max_bond, cutoff=cutoff, absorb=absorb
    )
