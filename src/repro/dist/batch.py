"""Batched block-contraction execution and compile-once shape bucketing.

Two pieces of the same idea — make the block structure *regular* so the
hardware (and XLA's trace cache) sees few large shapes instead of many small
ones, the design Menczer et al. (arXiv:2407.07411) show unlocks near-peak
DMRG throughput:

1. ``execute_batched`` runs a ``ContractionPlan``'s shape-bucket table
   (``plan.batched``): per bucket one stacked batched GEMM over all
   same-(M, K, N) block pairs with a segment-sum scatter into output slots,
   replacing O(num_pairs) tiny dots with O(num_buckets) large ones.  The
   GEMM+scatter goes through ``kernels.block_gemm.ops.block_sparse_matmul``,
   whose compiled executables are keyed by (P, M, K, N) alone — shared
   across plans, sites and sweeps — and which lowers to the Pallas
   ``block_gemm`` kernel when ``use_kernel=True``.

2. ``pad_block_sparse`` rounds every sector dimension up to a small set of
   bucket sizes (powers of two).  Zero-padding is exact for contractions —
   padded rows/columns of the operator are zero, so the padded matvec equals
   the padding of the true matvec — and it quantizes the traced block
   structure, so the jitted Davidson matvec stops retracing every time a
   sweep's truncated SVD shifts a bond sector dimension by one.

Equality guarantee: buckets execute the exact per-pair flops (no padding of
M/K/N), so ``execute_batched`` equals the list algorithm block-for-block up
to floating-point accumulation order (<=1e-13 on random f64 tensors,
tests/test_batch.py; DMRG energies <1e-10 vs seed).  Host-sync count: zero —
everything here dispatches device work and returns without blocking; the
only host reads in a sweep are Davidson's Rayleigh-Ritz step and the SVD
truncation sync, both outside this module.

Mesh-axis mapping: none of its own.  The batched path is mesh-agnostic —
tensor dims map to bucket-local (M, K, N) matricized axes, not mesh axes;
index tables are memoized per mesh (``memo_dev_idx``) only so plans shared
across policies never replay buffers committed under another mesh.  The
mapping of bucket axes onto the ("row", "col") mesh lives in
``dist/spmd.py`` (P over "row", N over "col"), injected here through the
``gemm_fn`` hook of ``execute_batched`` / ``execute_batched_blocks``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.block_gemm.ops import block_sparse_matmul
from ..tensor.blocksparse import BlockKey, BlockSparseTensor
from ..tensor.qn import Index
from . import faults
from .plan import ContractionPlan, bucket_dim

BlockMats = Dict[BlockKey, jax.Array]


def is_tracing(t: BlockSparseTensor) -> bool:
    """True if any block of ``t`` is a jax tracer (i.e. we're under jit).

    Shared by the contraction and decomposition engines so the
    tracer-handling policy (skip placement / refuse host syncs) cannot
    diverge between the two.
    """
    return any(isinstance(b, jax.core.Tracer) for b in t.blocks.values())


def execute_pairs(
    plan: ContractionPlan, a_blocks: Dict, b_blocks: Dict
) -> Dict:
    """Execute a plan's pair table as one tensordot per pair, into a dict.

    The list algorithm's numeric half, shared by the engine's "list"
    backend and the fused env core (``dist/envcore.py``) so the
    accumulation order — the basis of the <1e-10 seed-equality guarantee
    both advertise — cannot diverge between them.  Under jit the loop
    unrolls into the enclosing XLA program.
    """
    ax = (plan.ax_a, plan.ax_b)
    out: Dict = {}
    for ka, kb, kc in plan.pairs:
        piece = jnp.tensordot(a_blocks[ka], b_blocks[kb], axes=ax)
        out[kc] = out[kc] + piece if kc in out else piece
    return out


def matricize_lhs(
    t, keep: Tuple[int, ...], ax: Tuple[int, ...]
) -> BlockMats:
    """2-D (kept-rows, contracted-cols) form of every block of ``t``.

    Depends only on the contraction's static axes, not on the partner's block
    structure, so for the fixed Davidson operands (A, W_j, W_{j+1}, B) it can
    be computed once per solve instead of inside every matvec call.
    ``t`` may be a ``BlockSparseTensor`` or a bare key->array block dict
    (the fused env cores hold intermediates as dicts).
    """
    perm = keep + ax
    out: BlockMats = {}
    blocks = t.blocks if isinstance(t, BlockSparseTensor) else t
    for key, blk in blocks.items():
        shape = blk.shape
        r = 1
        for i in keep:
            r *= shape[i]
        out[key] = jnp.transpose(blk, perm).reshape(r, -1)
    return out


def matricize_rhs(
    t, keep: Tuple[int, ...], ax: Tuple[int, ...]
) -> BlockMats:
    """2-D (contracted-rows, kept-cols) form of every block of ``t``
    (tensor or bare block dict, like ``matricize_lhs``)."""
    perm = ax + keep
    out: BlockMats = {}
    blocks = t.blocks if isinstance(t, BlockSparseTensor) else t
    for key, blk in blocks.items():
        shape = blk.shape
        r = 1
        for i in ax:
            r *= shape[i]
        out[key] = jnp.transpose(blk, perm).reshape(r, -1)
    return out


def memo_dev_idx(layout, mesh, tracing: bool, host_arrays):
    """Device copies of a layout's index tables, memoized per mesh.

    ``host_arrays`` is any (nested) tuple of numpy arrays; the same-shape
    tuple of device arrays is cached on ``layout.dev_idx`` keyed by the mesh
    object (None when no shard policy is attached), so a plan cached
    globally never replays index arrays committed under a different mesh.
    Under jit tracing the host numpy arrays are returned directly (they fold
    into the trace as constants); memoizing there would leak tracers.
    Shared by the batched (``BatchedLayout``) and csr (``CsrLayout``)
    backends so the cross-mesh/tracer-leak handling cannot diverge.

    This memo is also the persistence boundary for device state: plans
    loaded from a ``PlanStore`` (dist/persist.py) arrive with ``dev_idx``
    stripped by the layouts' ``__getstate__`` — device buffers belong to
    the process that committed them, never to a pickle — and this lazy
    re-commit rebuilds them on first use in the loading process.
    """
    if tracing:
        return host_arrays
    cached = layout.dev_idx.get(mesh)
    if cached is None:
        cached = jax.tree_util.tree_map(jnp.asarray, host_arrays)
        layout.dev_idx[mesh] = cached
    return cached


def execute_batched_blocks(
    plan: ContractionPlan,
    a_mats: BlockMats,
    b_mats: BlockMats,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
    mesh=None,
    gemm_fn=None,
) -> Dict[BlockKey, jax.Array]:
    """The bucket loop on pre-matricized blocks, returning output blocks.

    ``gemm_fn(lhs, rhs, oi, num_out)`` overrides the per-bucket GEMM
    (default ``block_sparse_matmul``); ``dist/spmd.py`` injects its
    shard_map collective GEMM here so the identical bucket/gather tables
    drive both the single-device and the SPMD execution.  Shared by
    ``execute_batched`` and the fused env cores.
    """
    layout = plan.batched
    tracing = any(
        isinstance(v, jax.core.Tracer)
        for mats in (a_mats, b_mats)
        for v in mats.values()
    )
    dev = memo_dev_idx(
        layout, mesh, tracing, tuple((b.li, b.ri, b.oi) for b in layout.buckets)
    )

    out_acc: Dict[BlockKey, jax.Array] = {}
    for bucket, (li, ri, oi) in zip(layout.buckets, dev):
        lhs = jnp.stack([a_mats[k] for k in bucket.a_keys])
        rhs = jnp.stack([b_mats[k] for k in bucket.b_keys])
        if not bucket.li_identity:
            lhs = lhs[li]
        if not bucket.ri_identity:
            rhs = rhs[ri]
        if gemm_fn is not None:
            out = gemm_fn(lhs, rhs, oi, len(bucket.out_keys))
        else:
            out = block_sparse_matmul(
                lhs,
                rhs,
                oi,
                len(bucket.out_keys),
                interpret=interpret,
                use_kernel=use_kernel,
            )
        for slot, kc in enumerate(bucket.out_keys):
            piece = out[slot]
            prev = out_acc.get(kc)
            out_acc[kc] = piece if prev is None else prev + piece
    return {
        kc: mat.reshape(plan.out_block_shape(kc)) for kc, mat in out_acc.items()
    }


def execute_batched(
    plan: ContractionPlan,
    a: BlockSparseTensor,
    b: BlockSparseTensor,
    *,
    a_mats: Optional[BlockMats] = None,
    b_mats: Optional[BlockMats] = None,
    use_kernel: bool = False,
    interpret: bool = False,
    mesh=None,
    gemm_fn=None,
) -> BlockSparseTensor:
    """Execute ``plan`` bucket-by-bucket as stacked batched GEMMs.

    ``a_mats`` / ``b_mats`` are optional pre-matricized operand blocks (from
    ``matricize_lhs`` / ``matricize_rhs``) for operands that are fixed across
    many calls; live operands are matricized here.  ``gemm_fn`` swaps the
    per-bucket GEMM (see ``execute_batched_blocks``).

    Backend-equality guarantee: buckets execute the exact per-pair flops
    (no padding), so the result equals the list algorithm block-for-block
    up to floating-point accumulation order (<=1e-13 on random tensors,
    tests/test_batch.py; DMRG energies <1e-10 vs seed).
    """
    if not plan.pairs:
        return BlockSparseTensor(plan.out_indices, {}, plan.out_charge)
    if a_mats is None:
        a_mats = matricize_lhs(a, plan.keep_a, plan.ax_a)
    if b_mats is None:
        b_mats = matricize_rhs(b, plan.keep_b, plan.ax_b)
    out_blocks = execute_batched_blocks(
        plan,
        a_mats,
        b_mats,
        use_kernel=use_kernel,
        interpret=interpret,
        mesh=mesh,
        gemm_fn=gemm_fn,
    )
    # fault point: NaN-poison one bucket's output, simulating a bad GEMM on
    # a flaky node.  Never under tracing — a trace-time NaN would be baked
    # into a compiled executable cached far beyond the fault's lifetime.
    tracing = any(
        isinstance(v, jax.core.Tracer) for v in out_blocks.values()
    )
    if not tracing and out_blocks and faults.fire("batch.gemm_nan") is not None:
        k0 = next(iter(out_blocks))
        out_blocks[k0] = jnp.full_like(out_blocks[k0], jnp.nan)
    return BlockSparseTensor(plan.out_indices, out_blocks, plan.out_charge)


# --------------------------------------------------------- compile-once pads
# bucket_dim (power-of-two rounding) lives in plan.py, shared with the
# decomposition plan's SVD shape buckets; re-exported here for compat.


def pad_index(ix: Index) -> Index:
    """Same charges/flow, sector dims rounded up to bucket sizes."""
    return Index(
        tuple((q, bucket_dim(d)) for q, d in ix.sectors), ix.flow, ix.name
    )


def pad_block_sparse(t: BlockSparseTensor) -> BlockSparseTensor:
    """Zero-pad every block so all sector dims are bucket sizes.

    The padded tensor has the same charges, flows and block keys; only the
    degeneracies grow.  Because padding both members of every contracted
    index pair identically keeps them contractible, and the padded entries
    of all operands are zero, any contraction of padded tensors equals the
    padding of the unpadded contraction exactly.
    """
    out = BlockSparseTensor(tuple(pad_index(ix) for ix in t.indices), {}, t.charge)
    blocks: Dict[BlockKey, jax.Array] = {}
    for k, blk in t.blocks.items():
        tgt = out.block_shape(k)
        if tgt == tuple(blk.shape):
            blocks[k] = blk
        else:
            blocks[k] = jnp.pad(
                blk, tuple((0, ts - s) for ts, s in zip(tgt, blk.shape))
            )
    out.blocks = blocks
    return out


def unpad_block_sparse(
    t: BlockSparseTensor, indices: Tuple[Index, ...]
) -> BlockSparseTensor:
    """Slice a padded tensor back to the given (original) index structure."""
    out = BlockSparseTensor(indices, {}, t.charge)
    blocks: Dict[BlockKey, jax.Array] = {}
    for k, blk in t.blocks.items():
        tgt = out.block_shape(k)
        if tgt == tuple(blk.shape):
            blocks[k] = blk
        else:
            blocks[k] = blk[tuple(slice(0, s) for s in tgt)]
    out.blocks = blocks
    return out
