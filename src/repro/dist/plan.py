"""Contraction and decomposition plans: the static, cacheable half.

Everything the list / dense / csr algorithms derive from quantum numbers —
the (lhs, rhs) -> out block-pair table, output indices and charge, output
block shapes, matricized (row, col) dims and padded batch shapes — is a pure
function of ``(a.indices, a.charge, a block keys, b.indices, b.charge,
b block keys, axes)``.  The seed code re-derived all of it in Python on every
``contract()`` call, i.e. 4 contractions x davidson_iters x 2N sites per
sweep.  A ``ContractionPlan`` computes it once and a ``PlanCache`` keyed by
that structural signature reuses it for the whole sweep (the analogue of
CTF's one-time output-sparsity precomputation, paper Sec. IV-B).

The same split applies to the blockwise truncated SVD (paper Fig. 1e): a
``DecompositionPlan`` precomputes sector grouping, row/column layouts and
the gather tables that assemble each padded sector-matrix stack, cached in
a ``DecompPlanCache`` by the analogous ``decomp_signature``; execution lives
in ``dist/decomp.py``.

And to the environment stage (paper Fig. 1d, Sec. II-C): an
``EnvironmentPlan`` chains the three per-site contraction plans of
``extend_left`` / ``extend_right`` into one resolved pipeline — every
intermediate block structure precomputed — cached in an ``EnvPlanCache`` by
the composite ``env_signature`` of the (env, site, MPO) triple; execution
lives in ``dist/envcore.py``.

Plans hold only Python/numpy metadata — no jax arrays — so building them
never touches a device and they are safe to share across jit traces (block
keys and Index metadata are concrete even under tracing).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..tensor.blocksparse import BlockKey, BlockSparseTensor
from ..tensor.qn import Charge, Index, qadd, qscale, qzero

PlanSignature = Tuple

Axes = Tuple[Tuple[int, ...], Tuple[int, ...]]

# Process-wide persistent plan store (dist/persist.py sets this via
# ``activate_store``).  Lives here — not in persist.py — so the caches can
# consult it without importing persist (which imports this module).  A cache
# instance's own ``store`` attribute, when set, takes precedence.
_ACTIVE_STORE = None


def plan_signature(
    a: BlockSparseTensor, b: BlockSparseTensor, axes: Axes
) -> PlanSignature:
    """Structural signature of a contraction: indices, charges, keys, axes.

    Two contractions with equal signatures have identical symbolic structure
    (same pair table, same output blocks), whatever their numeric contents.
    Index is a frozen dataclass (name excluded from equality) and charges /
    keys are int tuples, so the signature is hashable.
    """
    ax_a, ax_b = tuple(axes[0]), tuple(axes[1])
    return (
        a.indices,
        a.charge,
        tuple(sorted(a.blocks)),
        b.indices,
        b.charge,
        tuple(sorted(b.blocks)),
        ax_a,
        ax_b,
    )


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def bucket_dim(d: int) -> int:
    """Round a dimension up to the next power of two (shape-bucket size)."""
    p = 1
    while p < d:
        p *= 2
    return p


def svd_flop_estimate(rp: int, cp: int) -> float:
    """~LAPACK gesdd flop estimate for one [rp, cp] economy SVD.

    Single source of truth for the decomposition cost model: used for
    ``DecompositionPlan.svd_flops`` and by the engine's auto rsvd-vs-svd
    choice and ``svd_flops`` stats counter (dist/decomp.py).
    """
    kp = min(rp, cp)
    return 8.0 * rp * cp * kp + 9.0 * kp**3


@dataclasses.dataclass
class CsrLayout:
    """Packed-batch layout for the block-CSR backend (see block_csr.py)."""

    a_keys: Tuple[BlockKey, ...]          # participating lhs keys, pack order
    b_keys: Tuple[BlockKey, ...]          # participating rhs keys, pack order
    bm: int                               # padded matricized row dim
    bk: int                               # padded contracted dim
    bn: int                               # padded matricized col dim
    li: np.ndarray                        # [P] lhs pack slot per pair
    ri: np.ndarray                        # [P] rhs pack slot per pair
    oi: np.ndarray                        # [P] output slot per pair (sorted)
    out_keys: Tuple[BlockKey, ...]        # output key per output slot
    out_rc: Tuple[Tuple[int, int], ...]   # unpadded (rows, cols) per out slot
    # (li, ri, oi) device arrays memoized PER MESH: plans live in the global
    # cache and outlive any one shard policy, so arrays committed under one
    # mesh must not be replayed under another (keyed None = no policy)
    dev_idx: Dict = dataclasses.field(default_factory=dict)

    # device arrays are process-local handles: never persisted, rebuilt by
    # ``batch.memo_dev_idx`` on first use in the loading process
    def __getstate__(self):
        state = dict(self.__dict__)
        state["dev_idx"] = {}
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.dev_idx = {}


@dataclasses.dataclass
class ShapeBucket:
    """All block pairs of a contraction sharing one matricized (M, K, N).

    Every lhs block in the bucket matricizes to exactly (m, k) and every rhs
    block to (k, n) — no padding — so the bucket executes as ONE stacked
    batched GEMM with a segment-sum scatter over its output slots (the
    fused same-shape batches of Menczer et al., arXiv:2407.07411).
    """

    m: int
    k: int
    n: int
    a_keys: Tuple[BlockKey, ...]          # unique participating lhs keys
    b_keys: Tuple[BlockKey, ...]          # unique participating rhs keys
    li: np.ndarray                        # [P] lhs slot per pair
    ri: np.ndarray                        # [P] rhs slot per pair
    oi: np.ndarray                        # [P] output slot per pair, ascending
    out_keys: Tuple[BlockKey, ...]        # bucket-local output key per slot
    li_identity: bool = False             # li == arange(P): gather is a no-op
    ri_identity: bool = False


@dataclasses.dataclass
class BatchedLayout:
    """Shape-group table: the pair list bucketed by matricized (M, K, N)."""

    buckets: Tuple[ShapeBucket, ...]
    num_unique: int                       # sum over buckets of |a_keys|+|b_keys|
    num_out_slots: int                    # sum over buckets of |out_keys|
    dev_idx: Dict = dataclasses.field(default_factory=dict)  # per-mesh, as CsrLayout

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    # per-mesh device handles: dropped on pickle, exactly like CsrLayout
    def __getstate__(self):
        state = dict(self.__dict__)
        state["dev_idx"] = {}
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self.dev_idx = {}


@dataclasses.dataclass
class ContractionPlan:
    """Precomputed symbolic structure of one block-sparse contraction."""

    signature: PlanSignature
    ax_a: Tuple[int, ...]
    ax_b: Tuple[int, ...]
    keep_a: Tuple[int, ...]
    keep_b: Tuple[int, ...]
    out_indices: Tuple[Index, ...]
    out_charge: Charge
    # (ka, kb, kc) per multiplied block pair, recorded in the block-dict
    # insertion order of the tensors the plan was built from — the same order
    # seed `contract` iterates.  On a cache hit from a structurally-equal
    # tensor with a *different* insertion order, the multiset of pairs is
    # identical but the accumulation order is the plan builder's, so results
    # may differ from seed in the last ulp (well inside the 1e-10 contract).
    pairs: Tuple[Tuple[BlockKey, BlockKey, BlockKey], ...]
    out_keys: Tuple[BlockKey, ...]        # unique output keys, first-seen order
    # cost model inputs
    flops_list: float                     # sum over pairs of 2*M*K*N
    flops_dense: float                    # one dense tensordot over full dims
    num_in_blocks: int = 0                # len(a.blocks) + len(b.blocks)
    _csr: Optional[CsrLayout] = None
    _batched: Optional[BatchedLayout] = None
    _dense_out_slices: Optional[Tuple[Tuple[BlockKey, Tuple[slice, ...]], ...]] = None

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        a: BlockSparseTensor, b: BlockSparseTensor, axes: Axes
    ) -> "ContractionPlan":
        ax_a, ax_b = tuple(axes[0]), tuple(axes[1])
        assert len(ax_a) == len(ax_b)
        for ia, ib in zip(ax_a, ax_b):
            assert a.indices[ia].can_contract(b.indices[ib]), (
                f"mode {ia} of A cannot contract mode {ib} of B: "
                f"{a.indices[ia]} vs {b.indices[ib]}"
            )
        keep_a = tuple(i for i in range(a.ndim) if i not in ax_a)
        keep_b = tuple(i for i in range(b.ndim) if i not in ax_b)
        out_indices = tuple(a.indices[i] for i in keep_a) + tuple(
            b.indices[i] for i in keep_b
        )
        out_charge = qadd(a.charge, b.charge)

        b_by_sig: Dict[Tuple[int, ...], List[BlockKey]] = {}
        for kb in b.blocks:
            b_by_sig.setdefault(tuple(kb[i] for i in ax_b), []).append(kb)

        pairs: List[Tuple[BlockKey, BlockKey, BlockKey]] = []
        out_keys: List[BlockKey] = []
        seen: Dict[BlockKey, int] = {}
        flops_list = 0.0
        for ka in a.blocks:
            sig = tuple(ka[i] for i in ax_a)
            for kb in b_by_sig.get(sig, ()):
                kc = tuple(ka[i] for i in keep_a) + tuple(kb[i] for i in keep_b)
                if kc not in seen:
                    seen[kc] = len(out_keys)
                    out_keys.append(kc)
                pairs.append((ka, kb, kc))
                m = _prod(a.indices[i].sector_dim(ka[i]) for i in keep_a)
                k = _prod(a.indices[i].sector_dim(ka[i]) for i in ax_a)
                n = _prod(b.indices[i].sector_dim(kb[i]) for i in keep_b)
                flops_list += 2.0 * m * k * n

        dense_m = _prod(a.indices[i].dim for i in keep_a)
        dense_k = _prod(a.indices[i].dim for i in ax_a)
        dense_n = _prod(b.indices[i].dim for i in keep_b)
        flops_dense = 2.0 * dense_m * dense_k * dense_n

        plan = ContractionPlan(
            signature=plan_signature(a, b, axes),
            ax_a=ax_a,
            ax_b=ax_b,
            keep_a=keep_a,
            keep_b=keep_b,
            out_indices=out_indices,
            out_charge=out_charge,
            pairs=tuple(pairs),
            out_keys=tuple(out_keys),
            flops_list=flops_list,
            flops_dense=flops_dense,
            num_in_blocks=len(a.blocks) + len(b.blocks),
        )
        return plan

    @staticmethod
    def _mshape(
        indices: Tuple[Index, ...], key: BlockKey, keep, ax
    ) -> Tuple[int, int]:
        rows = _prod([indices[i].sector_dim(key[i]) for i in keep] or [1])
        cols = _prod([indices[i].sector_dim(key[i]) for i in ax] or [1])
        return rows, cols

    def _build_csr(self) -> CsrLayout:
        """Padded-batch layout: the csr half of block_csr.py, symbolically.

        Built lazily on first ``csr``/``flops_csr`` access so list/dense runs
        never pay for it; every input comes from the structural signature,
        not live tensors.
        """
        a_indices, _, a_keys_sorted, b_indices, _, b_keys_sorted = self.signature[:6]
        a_pos = {k: i for i, k in enumerate(a_keys_sorted)}
        b_pos = {k: i for i, k in enumerate(b_keys_sorted)}
        out_pos = {k: i for i, k in enumerate(self.out_keys)}
        trip = sorted(
            ((a_pos[ka], b_pos[kb], out_pos[kc]) for ka, kb, kc in self.pairs),
            key=lambda t: t[2],
        )
        part_a = sorted({t[0] for t in trip})
        part_b = sorted({t[1] for t in trip})
        bm = max(
            self._mshape(a_indices, a_keys_sorted[i], self.keep_a, self.ax_a)[0]
            for i in part_a
        )
        bk = max(
            max(
                self._mshape(a_indices, a_keys_sorted[i], self.keep_a, self.ax_a)[1]
                for i in part_a
            ),
            max(
                self._mshape(b_indices, b_keys_sorted[i], self.keep_b, self.ax_b)[1]
                for i in part_b
            ),
        )
        bn = max(
            self._mshape(b_indices, b_keys_sorted[i], self.keep_b, self.ax_b)[0]
            for i in part_b
        )
        a_remap = {i: n for n, i in enumerate(part_a)}
        b_remap = {i: n for n, i in enumerate(part_b)}
        nk = len(self.keep_a)
        out_rc = tuple(
            (
                _prod([self.out_indices[i].sector_dim(kc[i]) for i in range(nk)] or [1]),
                _prod(
                    [
                        self.out_indices[i].sector_dim(kc[i])
                        for i in range(nk, len(self.out_indices))
                    ]
                    or [1]
                ),
            )
            for kc in self.out_keys
        )
        return CsrLayout(
            a_keys=tuple(a_keys_sorted[i] for i in part_a),
            b_keys=tuple(b_keys_sorted[i] for i in part_b),
            bm=bm,
            bk=bk,
            bn=bn,
            li=np.array([a_remap[t[0]] for t in trip], np.int32),
            ri=np.array([b_remap[t[1]] for t in trip], np.int32),
            oi=np.array([t[2] for t in trip], np.int32),
            out_keys=self.out_keys,
            out_rc=out_rc,
        )

    def _build_batched(self) -> BatchedLayout:
        """Bucket the pair list by matricized (M, K, N) shape.

        Unlike the csr layout there is NO padding: pairs only share a bucket
        when their matricized shapes are exactly equal, so each bucket is one
        regular [P, M, K] x [P, K, N] batched GEMM whose products segment-sum
        into the bucket's output slots.  Different buckets may feed the same
        output block (same kept sectors, different contracted sector dims);
        the executor accumulates across buckets in Python — a handful of adds.
        """
        a_indices, _, _, b_indices = self.signature[:4]
        groups: Dict[Tuple[int, int, int], List[Tuple[BlockKey, BlockKey, BlockKey]]] = {}
        for ka, kb, kc in self.pairs:
            m, k = self._mshape(a_indices, ka, self.keep_a, self.ax_a)
            n = self._mshape(b_indices, kb, self.keep_b, self.ax_b)[0]
            groups.setdefault((m, k, n), []).append((ka, kb, kc))

        buckets: List[ShapeBucket] = []
        num_unique = 0
        num_out_slots = 0
        for (m, k, n), prs in sorted(groups.items()):
            prs = sorted(prs, key=lambda t: t[2])  # -> oi ascending
            a_keys: List[BlockKey] = []
            b_keys: List[BlockKey] = []
            out_keys: List[BlockKey] = []
            a_pos: Dict[BlockKey, int] = {}
            b_pos: Dict[BlockKey, int] = {}
            o_pos: Dict[BlockKey, int] = {}
            li, ri, oi = [], [], []
            for ka, kb, kc in prs:
                if ka not in a_pos:
                    a_pos[ka] = len(a_keys)
                    a_keys.append(ka)
                if kb not in b_pos:
                    b_pos[kb] = len(b_keys)
                    b_keys.append(kb)
                if kc not in o_pos:
                    o_pos[kc] = len(out_keys)
                    out_keys.append(kc)
                li.append(a_pos[ka])
                ri.append(b_pos[kb])
                oi.append(o_pos[kc])
            li = np.array(li, np.int32)
            ri = np.array(ri, np.int32)
            p = len(prs)
            buckets.append(
                ShapeBucket(
                    m=m,
                    k=k,
                    n=n,
                    a_keys=tuple(a_keys),
                    b_keys=tuple(b_keys),
                    li=li,
                    ri=ri,
                    oi=np.array(oi, np.int32),
                    out_keys=tuple(out_keys),
                    li_identity=len(a_keys) == p and bool((li == np.arange(p)).all()),
                    ri_identity=len(b_keys) == p and bool((ri == np.arange(p)).all()),
                )
            )
            num_unique += len(a_keys) + len(b_keys)
            num_out_slots += len(out_keys)
        return BatchedLayout(
            buckets=tuple(buckets),
            num_unique=num_unique,
            num_out_slots=num_out_slots,
        )

    @property
    def batched(self) -> BatchedLayout:
        if self._batched is None:
            self._batched = self._build_batched()
        return self._batched

    # ------------------------------------------------------- lazy dense layout
    def dense_out_slices(self) -> Tuple[Tuple[BlockKey, Tuple[slice, ...]], ...]:
        """All charge-legal output blocks and their dense-embedding slices.

        Matches seed ``BlockSparseTensor.from_dense`` (which extracts every
        valid key, including blocks that happen to be zero).  The valid-key
        enumeration is the expensive recursive part, so it is computed lazily
        on first dense execution and memoized on the plan.
        """
        if self._dense_out_slices is None:
            probe = BlockSparseTensor(self.out_indices, {}, self.out_charge)
            offs = [ix.offsets() for ix in self.out_indices]
            rows = []
            for k in probe.valid_keys():
                sl = tuple(
                    slice(offs[i][s], offs[i][s] + self.out_indices[i].sector_dim(s))
                    for i, s in enumerate(k)
                )
                rows.append((k, sl))
            self._dense_out_slices = tuple(rows)
        return self._dense_out_slices

    @property
    def csr(self) -> CsrLayout:
        assert self.pairs, "csr layout undefined for empty pair table"
        if self._csr is None:
            self._csr = self._build_csr()
        return self._csr

    @property
    def flops_csr(self) -> float:
        """Padded-batch csr flops: pairs * 2*BM*BK*BN (triggers lazy layout)."""
        if not self.pairs:
            return 0.0
        L = self.csr
        return 2.0 * len(self.pairs) * L.bm * L.bk * L.bn

    @property
    def num_pairs(self) -> int:
        return len(self.pairs)

    def out_block_shape(self, kc: BlockKey) -> Tuple[int, ...]:
        return tuple(ix.sector_dim(s) for ix, s in zip(self.out_indices, kc))

    def materialize(self, pair_overhead: float = 16384.0) -> "ContractionPlan":
        """Force the lazy layouts a run would build anyway, for persistence.

        Called by ``dist.persist.PlanStore.save_plan`` so the priming
        process pays the layout derivation once and every loading process
        gets it for free.  The batched layout is always worth carrying; the
        dense slice table (a recursive valid-key enumeration) only when the
        engine cost model could actually route this plan to the dense
        backend — mirrored here with the same default dispatch overhead as
        ``engine.PAIR_OVERHEAD_FLOPS``.
        """
        if self.pairs:
            _ = self.batched
        if self.flops_dense <= self.flops_list + pair_overhead * self.num_pairs:
            _ = self.dense_out_slices()
        return self


# ------------------------------------------------------------ decomposition
def decomp_signature(theta: BlockSparseTensor, n_row_modes: int) -> PlanSignature:
    """Structural signature of a blockwise SVD split.

    Everything a ``DecompositionPlan`` precomputes — sector grouping,
    row/column layouts, gather tables, padded bucket shapes — is a pure
    function of ``(theta.indices, theta.charge, theta block keys,
    n_row_modes)``, exactly like ``plan_signature`` for contractions.
    """
    return (
        theta.indices,
        theta.charge,
        tuple(sorted(theta.blocks)),
        n_row_modes,
    )


@dataclasses.dataclass
class SectorSplit:
    """Row/column layout of one fused-charge sector of the matricized theta.

    The sector matrix is ``[R, C]``: rows are the concatenation (in
    ``row_keys`` order) of the matricized row-mode blocks, columns likewise
    for the column modes — the same layout the seed ``svd_split`` builds with
    one ``.at[].set()`` per block.
    """

    q: Charge
    row_keys: Tuple[BlockKey, ...]       # sorted row-part keys
    col_keys: Tuple[BlockKey, ...]       # sorted col-part keys
    rdims: Tuple[int, ...]               # matricized row dim per row key
    cdims: Tuple[int, ...]               # matricized col dim per col key
    roffs: Tuple[int, ...]               # row offset per row key
    coffs: Tuple[int, ...]               # col offset per col key
    R: int                               # total (unpadded) rows
    C: int                               # total (unpadded) cols
    bucket: int = -1                     # index into plan.buckets
    slot: int = -1                       # stack position within the bucket

    @property
    def K(self) -> int:
        """True rank bound min(R, C): number of real singular values."""
        return min(self.R, self.C)


@dataclasses.dataclass
class SvdBucket:
    """All sectors sharing one padded matrix shape (Rp, Cp).

    The bucket executes as ONE batched ``jnp.linalg.svd`` over the stacked
    ``[S, Rp, Cp]`` sector matrices, assembled with a single gather from the
    flattened theta blocks (``gather`` indexes into the flat concatenation,
    with the one-past-the-end slot reading the appended zero — structural
    zeros and padding both land there).
    """

    rp: int                              # padded rows (bucket_dim(R))
    cp: int                              # padded cols (bucket_dim(C))
    sectors: Tuple[int, ...]             # indices into plan.sectors, stack order
    gather: np.ndarray                   # [S, rp, cp] int32 into flat_ext
    k_true: np.ndarray                   # [S] int32: min(R, C) per sector

    @property
    def kp(self) -> int:
        """Padded singular-value count min(rp, cp) per stacked sector."""
        return min(self.rp, self.cp)


@dataclasses.dataclass
class DecompositionPlan:
    """Precomputed symbolic structure of one blockwise truncated SVD.

    Holds only Python/numpy metadata (no jax arrays), like
    ``ContractionPlan``; building one never touches a device.  Executed by
    ``dist.decomp.DecompositionEngine``, whose batched path is guaranteed to
    match the seed ``svd_split_unplanned`` to <1e-10 up to the per-singular-
    vector sign gauge (products U·V, singular values and truncation error
    agree unconditionally).
    """

    signature: PlanSignature
    n_row_modes: int
    row_ix: Tuple[Index, ...]
    col_ix: Tuple[Index, ...]
    block_order: Tuple[BlockKey, ...]    # canonical (sorted) flattening order
    block_offsets: Tuple[int, ...]       # flat offset per block, same order
    nnz: int                             # total elements across blocks
    sectors: Tuple[SectorSplit, ...]     # sorted by fused charge (seed order)
    buckets: Tuple[SvdBucket, ...]
    svd_flops: float                     # full-SVD flop estimate over buckets
    # compiled executables keyed by (absorb, per-bucket method, sketch size);
    # stored on the plan (like CsrLayout.dev_idx) so engines sharing the
    # global cache also share compiles
    _exec: Dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(theta: BlockSparseTensor, n_row_modes: int) -> "DecompositionPlan":
        if not theta.blocks:
            raise ValueError("svd_split of a tensor with no blocks")
        indices = theta.indices
        row_ix = indices[:n_row_modes]
        col_ix = indices[n_row_modes:]

        block_order = tuple(sorted(theta.blocks))
        offsets: List[int] = []
        acc = 0
        sizes: Dict[BlockKey, int] = {}
        for k in block_order:
            offsets.append(acc)
            sz = _prod(indices[i].sector_dim(s) for i, s in enumerate(k))
            sizes[k] = sz
            acc += sz
        nnz = acc

        # group block keys by fused row charge (flow-weighted), as the seed
        groups: Dict[Charge, List[BlockKey]] = {}
        for k in block_order:
            q = qzero(indices[0].nq)
            for ix, s in zip(row_ix, k[:n_row_modes]):
                q = qadd(q, qscale(ix.charge(s), ix.flow))
            groups.setdefault(q, []).append(k)

        sectors: List[SectorSplit] = []
        sector_keys: List[List[BlockKey]] = []
        for q, keys in sorted(groups.items()):
            row_keys = sorted({k[:n_row_modes] for k in keys})
            col_keys = sorted({k[n_row_modes:] for k in keys})
            rdims = tuple(
                _prod([ix.sector_dim(s) for ix, s in zip(row_ix, rk)] or [1])
                for rk in row_keys
            )
            cdims = tuple(
                _prod([ix.sector_dim(s) for ix, s in zip(col_ix, ck)] or [1])
                for ck in col_keys
            )
            roffs, a = [], 0
            for d in rdims:
                roffs.append(a)
                a += d
            R = a
            coffs, a = [], 0
            for d in cdims:
                coffs.append(a)
                a += d
            C = a
            sectors.append(
                SectorSplit(
                    q=q,
                    row_keys=tuple(row_keys),
                    col_keys=tuple(col_keys),
                    rdims=rdims,
                    cdims=cdims,
                    roffs=tuple(roffs),
                    coffs=tuple(coffs),
                    R=R,
                    C=C,
                )
            )
            sector_keys.append(keys)

        # bucket sectors by padded (Rp, Cp); build one gather table per bucket
        by_shape: Dict[Tuple[int, int], List[int]] = {}
        for si, sec in enumerate(sectors):
            by_shape.setdefault((bucket_dim(sec.R), bucket_dim(sec.C)), []).append(si)

        buckets: List[SvdBucket] = []
        svd_flops = 0.0
        key_offset = {k: o for k, o in zip(block_order, offsets)}
        for (rp, cp), sec_ids in sorted(by_shape.items()):
            gather = np.full((len(sec_ids), rp, cp), nnz, np.int32)
            for slot, si in enumerate(sec_ids):
                sec = sectors[si]
                sec.bucket = len(buckets)
                sec.slot = slot
                rpos = {rk: i for i, rk in enumerate(sec.row_keys)}
                cpos = {ck: i for i, ck in enumerate(sec.col_keys)}
                for k in sector_keys[si]:
                    ri = rpos[k[:n_row_modes]]
                    ci = cpos[k[n_row_modes:]]
                    rd, cd = sec.rdims[ri], sec.cdims[ci]
                    # block elements are already in (row-modes, col-modes)
                    # C order, so the flat block reshapes to [rd, cd] directly
                    idx = key_offset[k] + np.arange(rd * cd, dtype=np.int32)
                    gather[
                        slot,
                        sec.roffs[ri] : sec.roffs[ri] + rd,
                        sec.coffs[ci] : sec.coffs[ci] + cd,
                    ] = idx.reshape(rd, cd)
            svd_flops += len(sec_ids) * svd_flop_estimate(rp, cp)
            buckets.append(
                SvdBucket(
                    rp=rp,
                    cp=cp,
                    sectors=tuple(sec_ids),
                    gather=gather,
                    k_true=np.array(
                        [sectors[si].K for si in sec_ids], np.int32
                    ),
                )
            )

        return DecompositionPlan(
            signature=decomp_signature(theta, n_row_modes),
            n_row_modes=n_row_modes,
            row_ix=tuple(row_ix),
            col_ix=tuple(col_ix),
            block_order=block_order,
            block_offsets=tuple(offsets),
            nnz=nnz,
            sectors=tuple(sectors),
            buckets=tuple(buckets),
            svd_flops=svd_flops,
        )

    @property
    def num_sectors(self) -> int:
        return len(self.sectors)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    # compiled executables are process-local (they close over an engine and
    # a live XLA client): never persisted, rebuilt lazily by the loading
    # process's DecompositionEngine — where the persistent compilation cache
    # and the export store make the rebuild cheap
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_exec"] = {}
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._exec = {}


# ------------------------------------------------------------- environments
def env_signature(
    env: BlockSparseTensor,
    site: BlockSparseTensor,
    mpo: BlockSparseTensor,
    side: str,
) -> PlanSignature:
    """Composite structural signature of one environment update.

    The fused left/right env update (``dist/envcore.py``) is a pure function
    of the (env, site, MPO) triple's structure plus the sweep direction —
    the same structural-signature contract as ``plan_signature`` /
    ``decomp_signature``, extended to a three-tensor pipeline.
    """
    return (
        "env",
        side,
        env.indices,
        env.charge,
        tuple(sorted(env.blocks)),
        site.indices,
        site.charge,
        tuple(sorted(site.blocks)),
        mpo.indices,
        mpo.charge,
        tuple(sorted(mpo.blocks)),
    )


def _probe(
    indices: Tuple[Index, ...], charge: Charge, keys
) -> BlockSparseTensor:
    """Structure-only tensor (blocks map to None): plan building and
    signatures read block *keys* only, never block values."""
    return BlockSparseTensor(indices, dict.fromkeys(keys), charge)


def _conj_probe(t: BlockSparseTensor) -> BlockSparseTensor:
    """Structural image of ``t.conj()``: dual indices, negated charge,
    same block keys (conj never moves blocks)."""
    return _probe(
        tuple(ix.dual() for ix in t.indices),
        qscale(t.charge, -1),
        t.blocks,
    )


# the three chained contractions of extend_left / extend_right
# (core/env.py), as static axes per step, plus the final transpose
_ENV_LEFT_AXES = (((2,), (0,)), ((1, 2), (0, 2)), ((0, 1), (0, 2)))
_ENV_LEFT_PERM = (0, 2, 1)
_ENV_RIGHT_AXES = (((2,), (2,)), ((3, 1), (3, 2)), ((1, 3), (2, 1)))
_ENV_RIGHT_PERM = (2, 1, 0)


@dataclasses.dataclass
class EnvironmentPlan:
    """Precomputed symbolic structure of one fused env update.

    Chains the three per-site ``ContractionPlan``s of ``extend_left`` /
    ``extend_right`` (fetched through the shared contraction ``PlanCache``,
    so the eager three-call path and the fused core reuse the same step
    plans) plus the final transpose, resolving every intermediate block
    structure ahead of time.  Holds only Python/numpy metadata; executed by
    ``dist.envcore.EnvironmentEngine`` as ONE jitted core per structure.
    """

    signature: PlanSignature
    side: str                             # "left" | "right"
    steps: Tuple[ContractionPlan, ContractionPlan, ContractionPlan]
    perm: Tuple[int, ...]                 # final transpose of step-3 output
    env_keys: Tuple[BlockKey, ...]        # sorted operand keys, core arg order
    site_keys: Tuple[BlockKey, ...]
    mpo_keys: Tuple[BlockKey, ...]
    out_indices: Tuple[Index, ...]        # post-transpose env structure
    out_charge: Charge
    out_keys: Tuple[BlockKey, ...]        # post-transpose, sorted
    pre_out_keys: Tuple[BlockKey, ...]    # step-3 key per out_keys entry
    flops: float                          # sum over steps of flops_list
    # compiled fused cores keyed by the executing engine's jit flag; stored
    # on the plan (like DecompositionPlan._exec) so engines sharing the
    # cache also share compiles
    _exec: Dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        env: BlockSparseTensor,
        site: BlockSparseTensor,
        mpo: BlockSparseTensor,
        side: str,
        cache: Optional["PlanCache"] = None,
    ) -> "EnvironmentPlan":
        assert side in ("left", "right")
        cache = cache if cache is not None else global_plan_cache
        bra = _conj_probe(site)
        if side == "left":
            ax1, ax2, ax3 = _ENV_LEFT_AXES
            perm = _ENV_LEFT_PERM
            p1 = cache.get(env, site, ax1)
            t1 = _probe(p1.out_indices, p1.out_charge, p1.out_keys)
            p2 = cache.get(t1, mpo, ax2)
            t2 = _probe(p2.out_indices, p2.out_charge, p2.out_keys)
            p3 = cache.get(bra, t2, ax3)
        else:
            ax1, ax2, ax3 = _ENV_RIGHT_AXES
            perm = _ENV_RIGHT_PERM
            p1 = cache.get(site, env, ax1)
            t1 = _probe(p1.out_indices, p1.out_charge, p1.out_keys)
            p2 = cache.get(t1, mpo, ax2)
            t2 = _probe(p2.out_indices, p2.out_charge, p2.out_keys)
            p3 = cache.get(t2, bra, ax3)
        post_to_pre = {
            tuple(k[p] for p in perm): k for k in p3.out_keys
        }
        out_keys = tuple(sorted(post_to_pre))
        return EnvironmentPlan(
            signature=env_signature(env, site, mpo, side),
            side=side,
            steps=(p1, p2, p3),
            perm=perm,
            env_keys=tuple(sorted(env.blocks)),
            site_keys=tuple(sorted(site.blocks)),
            mpo_keys=tuple(sorted(mpo.blocks)),
            out_indices=tuple(p3.out_indices[p] for p in perm),
            out_charge=p3.out_charge,
            out_keys=out_keys,
            pre_out_keys=tuple(post_to_pre[k] for k in out_keys),
            flops=p1.flops_list + p2.flops_list + p3.flops_list,
        )

    # compiled fused cores are process-local, exactly like DecompositionPlan
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_exec"] = {}
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._exec = {}


# ------------------------------------------------------------------- caches
class _SignatureLRU:
    """LRU cache of plans keyed by structural signature.

    ``hits``/``misses``/``evictions`` count lookups and capacity evictions;
    ``size`` is live entries.  Shared machinery for contraction and
    decomposition plans — subclasses provide ``_signature`` and ``_build``.

    Thread-safe: the serving subsystem (``repro/serve``) builds problems and
    fetches plans from multiple threads against the module-level global
    caches, so every mutation happens under a per-cache lock.  Builds run
    inside the lock on purpose — a plan object carries its compiled cores
    (``_exec``), so two racing builds of the same signature would silently
    drop one core set.  Lock ordering is acyclic: an ``EnvPlanCache`` build
    acquires the contraction ``PlanCache`` lock (for its three step plans),
    never the reverse.

    Persistence (dist/persist.py): on an in-memory miss the cache consults
    its attached ``PlanStore`` (``self.store``, else the process-wide
    ``_ACTIVE_STORE``) before building, and writes every fresh build back.
    ``builds`` counts actual ``_build`` invocations — with a primed store
    it stays zero, the property the cold-start regression test pins down.
    """

    # persist.PLAN_KINDS entry naming this cache's store subdirectory
    kind = "contraction"

    def __init__(self, maxsize: int = 4096):
        self.maxsize = maxsize
        self._plans: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.builds = 0
        self.store = None  # per-cache PlanStore override (None = _ACTIVE_STORE)

    def _get(self, sig, build):
        with self._lock:
            plan = self._plans.get(sig)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(sig)
                return plan
            self.misses += 1
            store = self.store if self.store is not None else _ACTIVE_STORE
            plan = store.load_plan(self.kind, sig) if store is not None else None
            if plan is None:
                self.builds += 1
                plan = build()
                if store is not None:
                    store.save_plan(self.kind, sig, plan)
            self._plans[sig] = plan
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
                self.evictions += 1
            return plan

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self):
        with self._lock:
            self._plans.clear()
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.builds = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "builds": self.builds,
                "size": len(self._plans),
            }


class PlanCache(_SignatureLRU):
    """LRU cache of ContractionPlans keyed by structural signature."""

    def get(
        self, a: BlockSparseTensor, b: BlockSparseTensor, axes: Axes
    ) -> ContractionPlan:
        sig = plan_signature(a, b, axes)
        return self._get(sig, lambda: ContractionPlan.build(a, b, axes))


class DecompPlanCache(_SignatureLRU):
    """LRU cache of DecompositionPlans keyed by structural signature."""

    kind = "decomp"

    def get(self, theta: BlockSparseTensor, n_row_modes: int) -> DecompositionPlan:
        sig = decomp_signature(theta, n_row_modes)
        return self._get(sig, lambda: DecompositionPlan.build(theta, n_row_modes))


class EnvPlanCache(_SignatureLRU):
    """LRU cache of EnvironmentPlans keyed by composite triple signature.

    ``contraction_cache`` is where the three chained step plans are fetched
    from (the global contraction cache by default, so the eager three-call
    path and the fused core share step plans).
    """

    kind = "env"

    def __init__(
        self, maxsize: int = 4096, contraction_cache: Optional[PlanCache] = None
    ):
        super().__init__(maxsize)
        self.contraction_cache = contraction_cache

    def get(
        self,
        env: BlockSparseTensor,
        site: BlockSparseTensor,
        mpo: BlockSparseTensor,
        side: str,
    ) -> EnvironmentPlan:
        sig = env_signature(env, site, mpo, side)
        return self._get(
            sig,
            lambda: EnvironmentPlan.build(
                env, site, mpo, side, cache=self.contraction_cache
            ),
        )


global_plan_cache = PlanCache()
global_decomp_cache = DecompPlanCache()
global_env_cache = EnvPlanCache()


def get_plan(
    a: BlockSparseTensor,
    b: BlockSparseTensor,
    axes: Axes,
    cache: Optional[PlanCache] = None,
) -> ContractionPlan:
    """Fetch (or build) the ContractionPlan for this structural signature."""
    return (cache or global_plan_cache).get(a, b, axes)


def get_decomp_plan(
    theta: BlockSparseTensor,
    n_row_modes: int,
    cache: Optional[DecompPlanCache] = None,
) -> DecompositionPlan:
    """Fetch (or build) the DecompositionPlan for this structural signature."""
    return (cache or global_decomp_cache).get(theta, n_row_modes)


def get_env_plan(
    env: BlockSparseTensor,
    site: BlockSparseTensor,
    mpo: BlockSparseTensor,
    side: str,
    cache: Optional[EnvPlanCache] = None,
) -> EnvironmentPlan:
    """Fetch (or build) the EnvironmentPlan for this triple's signature."""
    return (cache or global_env_cache).get(env, site, mpo, side)
