"""Distributed contraction engine (DESIGN.md Sec. 3).

Three layers, mirroring the paper's separation of symbolic planning from
numeric execution:

- ``plan``:   ``ContractionPlan`` — the static (lhs, rhs) -> out block-pair
              table, output indices/charges and matricized shapes, derived
              once per block structure and cached by structural signature.
- ``shard``:  ``BlockShardPolicy`` — places each block's row/column modes on
              mesh axes (the paper's "every block over all processors"
              layout), with divisibility-aware fallback to replication.
- ``batch``:  shape-bucketed batched execution (stacked same-shape GEMMs +
              segment-sum scatter) and the power-of-two sector padding that
              makes the jitted matvec compile once instead of per site.
- ``engine``: ``ContractionEngine`` — executes plans through a pluggable
              list / dense / csr / batched backend chosen by a
              flop-and-dispatch cost model, and jits the planned two-site
              matvec.
"""
from .batch import pad_block_sparse, unpad_block_sparse
from .engine import ContractionEngine
from .plan import ContractionPlan, PlanCache, get_plan, global_plan_cache
from .shard import BlockShardPolicy, make_block_mesh

__all__ = [
    "ContractionEngine",
    "ContractionPlan",
    "PlanCache",
    "get_plan",
    "global_plan_cache",
    "BlockShardPolicy",
    "make_block_mesh",
    "pad_block_sparse",
    "unpad_block_sparse",
]
