"""Distributed contraction + decomposition engines (DESIGN.md Sec. 3).

Layers, mirroring the paper's separation of symbolic planning from numeric
execution:

- ``plan``:   ``ContractionPlan`` — the static (lhs, rhs) -> out block-pair
              table, output indices/charges and matricized shapes — and
              ``DecompositionPlan`` — sector grouping, row/col layouts and
              the gather tables of the blockwise SVD — each derived once per
              block structure and cached by structural signature.
- ``persist``: ``PlanStore`` — versioned on-disk persistence for the three
              plan caches, the JAX persistent compilation cache and
              ``jax.export``ed bucket cores, so a fresh process's first
              sweep skips the plan/trace/compile pipeline (DESIGN.md
              Sec. 3.9).
- ``shard``:  ``BlockShardPolicy`` — places blocks on the 2-D ("row",
              "col") mesh: "spmd" mode pins tensors device-resident
              (replicated, uploaded once) for shard_map compute; "storage"
              mode keeps the sharded-storage / gather-before-compute
              fallback with divisibility-aware mode assignment.
- ``spmd``:   the true-SPMD compute layer (DESIGN.md 3.10): each shape
              bucket's stacked GEMM as ONE shard_map program over the mesh
              (pairs over "row", output columns over "col", one psum + one
              tiled all_gather per bucket), plus the spmd variant of the
              fused env core and the process-wide collective ledger
              (``spmd.stats()``).
- ``batch``:  shape-bucketed batched contraction execution (stacked
              same-shape GEMMs + segment-sum scatter) and the power-of-two
              sector padding that makes the jitted matvec compile once.
- ``decomp``: ``DecompositionEngine`` — the blockwise truncated SVD executed
              as one batched ``jnp.linalg.svd`` per padded shape bucket,
              with a single host sync for the global truncation and an
              optional randomized-SVD path.
- ``envcore``: ``EnvironmentEngine`` — the left/right environment updates
              (and the startup right-to-left rebuild) executed as ONE fused
              jitted core per padded structure: the three chained
              contractions of ``extend_left``/``extend_right`` with no host
              round-trips between them.
- ``faults``: deterministic fault injection — named injection points armed
              via ``inject(...)`` / ``REPRO_FAULTS`` — plus the
              ``NumericalHealthError`` the health guards raise (DESIGN.md
              Sec. 3.8).
- ``engine``: ``ContractionEngine`` — executes plans through a pluggable
              list / dense / csr / batched backend chosen by a
              flop-and-dispatch cost model, jits the planned two-site
              matvec, and fronts the decomposition engine (``svd_split``)
              and the environment engine (``env_update_left/right``).

All execution paths compute the same physics: every backend and the planned
SVD agree with the seed algorithms to <1e-10 (tests/test_dist.py,
tests/test_batch.py, tests/test_decomp.py).
"""
from .batch import pad_block_sparse, unpad_block_sparse
from .decomp import DecompositionEngine, svd_split_planned
from .engine import ContractionEngine
from .envcore import EnvironmentEngine
from .faults import (
    FAULT_POINTS,
    FaultInjected,
    FaultRegistry,
    NumericalHealthError,
    inject,
    registry as fault_registry,
)
from .persist import (
    PERSIST_VERSION,
    PlanStore,
    activate_store,
    active_store,
    canonical_signature,
    deactivate_store,
    enable_compilation_cache,
    signature_digest,
    store_stats,
    using_store,
)
from .plan import (
    ContractionPlan,
    DecompPlanCache,
    DecompositionPlan,
    EnvPlanCache,
    EnvironmentPlan,
    PlanCache,
    get_decomp_plan,
    get_env_plan,
    get_plan,
    global_decomp_cache,
    global_env_cache,
    global_plan_cache,
)
from .shard import BlockShardPolicy, make_block_mesh
from .spmd import (
    make_spmd_gemm,
    spmd_bucket_gemm,
    stats as spmd_stats,
)


def cache_stats(*engines) -> dict:
    """One dict aggregating the three global plan caches' hit/miss/eviction
    counters plus any passed-in engine ``stats()`` ledgers.

    The serving subsystem's stats endpoint and the ``--stats-json`` flags on
    the example drivers dump this; keys are stable so dashboards can diff
    runs.  ``engines`` may be ``ContractionEngine`` instances (anything with
    a ``stats()`` method); their ledgers land under ``"engines"`` in call
    order.  ``plan_store`` is the active persistent store's ledger
    (hits/misses/saves/corrupt/stale plus the export family; see
    ``persist.PlanStore.stats``), or None when no store is attached.
    """
    out = {
        "plan_cache": global_plan_cache.stats(),
        "decomp_plan_cache": global_decomp_cache.stats(),
        "env_plan_cache": global_env_cache.stats(),
        "plan_store": store_stats(),
    }
    if engines:
        out["engines"] = [e.stats() for e in engines]
    return out


__all__ = [
    "ContractionEngine",
    "ContractionPlan",
    "DecompositionEngine",
    "DecompositionPlan",
    "DecompPlanCache",
    "EnvironmentEngine",
    "EnvironmentPlan",
    "EnvPlanCache",
    "PlanCache",
    "get_plan",
    "get_decomp_plan",
    "get_env_plan",
    "global_plan_cache",
    "global_decomp_cache",
    "global_env_cache",
    "cache_stats",
    "PERSIST_VERSION",
    "PlanStore",
    "activate_store",
    "active_store",
    "canonical_signature",
    "deactivate_store",
    "enable_compilation_cache",
    "signature_digest",
    "store_stats",
    "using_store",
    "FAULT_POINTS",
    "FaultInjected",
    "FaultRegistry",
    "NumericalHealthError",
    "inject",
    "fault_registry",
    "svd_split_planned",
    "BlockShardPolicy",
    "make_block_mesh",
    "make_spmd_gemm",
    "spmd_bucket_gemm",
    "spmd_stats",
    "pad_block_sparse",
    "unpad_block_sparse",
]
