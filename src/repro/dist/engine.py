"""ContractionEngine: plan-cached, mesh-sharded block-sparse contraction.

The engine is a drop-in replacement for the bare ``contract_fn`` threaded
through ``core/env.py`` / ``core/sweep.py``: it is callable as
``engine(a, b, axes)`` and returns a ``BlockSparseTensor``.  Per call it

1. fetches (or builds) the ``ContractionPlan`` for the contraction's
   structural signature from a ``PlanCache``, skipping the per-call hash
   join / charge bookkeeping the seed algorithms re-derive every time;
2. picks a backend — "list" (one tensordot per block pair), "dense" (embed +
   one GEMM), "batched" (shape-bucketed stacked GEMMs + segment-sum, see
   dist/batch.py), or "csr" (padded batched block GEMM) — either fixed or by
   a flop-and-dispatch cost model ("auto").  "auto" chooses between list,
   dense and batched; csr joins the auto candidate set only with
   ``allow_csr=True``, since without a real Pallas target (TPU) the csr
   execution path is not wall-time competitive however favorable its
   padded-flop count looks;
3. executes the plan and, when a ``BlockShardPolicy`` is attached, places the
   output blocks on the device mesh (outside jit; under tracing XLA owns
   layout).  Under an spmd-mode policy the backend choice is overridden:
   every contraction executes the batched bucket tables through the
   shard_map collective GEMM of ``dist/spmd.py`` (DESIGN.md 3.10), with
   operands device-resident and outputs replicated on the mesh.

``two_site_matvec`` is the planned Davidson matvec of paper Fig. 1d;
``matvec_fn`` optionally jits it.  Because ``BlockSparseTensor`` is a pytree
whose aux data (indices, charge, block keys) is static, jax's own trace cache
keys compiled executables by block structure, so repeated sweeps at the same
bond dimensions reuse both the plans and the compiled matvec.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.block_gemm.ops import block_sparse_matmul
from ..tensor.block_csr import pack_blocks
from ..tensor.blocksparse import BlockKey, BlockSparseTensor, contract
from .batch import (
    execute_batched,
    execute_pairs,
    is_tracing as _is_tracing,
    matricize_lhs,
    matricize_rhs,
    memo_dev_idx,
)
from . import persist, spmd as spmd_mod
from .decomp import DecompositionEngine
from .envcore import EnvironmentEngine
from .plan import Axes, ContractionPlan, PlanCache, global_plan_cache
from .shard import BlockShardPolicy

# cost-model overhead charged per dispatched block GEMM, in equivalent flops:
# on small DMRG blocks the per-op dispatch dominates, which is exactly why the
# paper's dense algorithm wins at small m (their Fig. 5 crossover).
PAIR_OVERHEAD_FLOPS = 16384.0

# Degradation ladder for a failed contraction backend (DESIGN.md 3.8): on an
# exception the engine retries each rung BELOW the failed one in this order,
# ending at the seed ``tensor.blocksparse.contract``.  Ordered fastest/most
# specialized first, so a failure costs the least capable machinery it can.
# "spmd" is only a valid rung under an spmd-mode policy (operands are then
# mesh-resident replicated, so every lower rung still computes correctly).
CONTRACTION_LADDER: Tuple[str, ...] = ("spmd", "csr", "batched", "dense", "list")


class ContractionEngine:
    """Executes cached ContractionPlans through a pluggable backend.

    Backend-equality guarantee: every backend ("list", "dense", "csr",
    "batched") and the "auto" cost-model choice computes the same
    charge-conserving contraction — output blocks match the seed list
    algorithm to <=1e-12 on random tensors and DMRG energies to <1e-10
    (tests/test_dist.py, tests/test_batch.py); sharding via ``policy`` is a
    pure layout hint and never changes values.  ``svd_split`` fronts the
    decomposition engine with the analogous guarantee (``dist.decomp``).
    ``stats()`` documents the units of every counter it reports.
    """

    def __init__(
        self,
        backend: str = "auto",
        cache: Optional[PlanCache] = None,
        policy: Optional[BlockShardPolicy] = None,
        *,
        use_kernel: bool = False,
        interpret: bool = False,  # compiled Pallas by default, like block_csr
        allow_csr: bool = False,
        pair_overhead: float = PAIR_OVERHEAD_FLOPS,
        decomp: Optional[DecompositionEngine] = None,
        env: Optional[EnvironmentEngine] = None,
    ):
        assert backend in ("auto", "list", "dense", "csr", "batched")
        self.backend = backend
        self.cache = cache if cache is not None else global_plan_cache
        self.policy = policy
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.allow_csr = allow_csr
        self.pair_overhead = pair_overhead
        # decomposition stage (dist/decomp.py): per-engine so stats() reports
        # this run's SVD counters, sharing the global DecompPlanCache
        self.decomp = decomp if decomp is not None else DecompositionEngine()
        # environment stage (dist/envcore.py): per-engine for the same
        # reason, sharing the global EnvPlanCache and its compiled cores
        self.env = env if env is not None else EnvironmentEngine()
        zero = {"list": 0, "dense": 0, "csr": 0, "batched": 0, "spmd": 0}
        self.backend_counts: Dict[str, int] = dict(zero)
        self.backend_flops: Dict[str, float] = {k: 0.0 for k in zero}
        self.backend_seconds: Dict[str, float] = {k: 0.0 for k in zero}
        self.jit_retraces = 0
        self._jit_mv = None
        # loaded/attempted matvec exports keyed by (conf, operand, x)
        # structure: deserializing + jit-wrapping an artifact costs real time,
        # so it must happen once per structure per process, not per solve
        self._export_mv: Dict = {}
        # degradation ladder ledger (DESIGN.md 3.8): stage-keyed counts of
        # failed first attempts and which lower rung recovered them.  Shared
        # with the sweep layer via note_retry/note_degradation so one
        # stats() call reports the whole run's recovery history.
        self.retries: Dict[str, int] = {}
        self.degradations: Dict[str, int] = {}

    # ------------------------------------------------------ health bookkeeping
    def note_retry(self, stage: str) -> None:
        """Record a failed first attempt at ``stage`` (sweep layers call this
        so per-run recovery counts live on the engine the run owns)."""
        self.retries[stage] = self.retries.get(stage, 0) + 1

    def note_degradation(self, stage: str) -> None:
        """Record that ``stage`` recovered on a lower ladder rung."""
        self.degradations[stage] = self.degradations.get(stage, 0) + 1

    # ----------------------------------------------------------------- entry
    def __call__(
        self,
        a: BlockSparseTensor,
        b: BlockSparseTensor,
        axes: Axes,
        *,
        a_mats=None,
        b_mats=None,
    ) -> BlockSparseTensor:
        plan = self.cache.get(a, b, axes)
        if self._spmd_mode:
            # spmd-mode policy: every contraction runs the shard_map bucket
            # GEMMs (dist/spmd.py) so compute partitions over the mesh
            backend = "spmd"
        elif self.backend != "auto":
            backend = self.backend
        else:
            backend = self.choose_backend(plan)
        self.backend_counts[backend] += 1
        self.backend_flops[backend] += self._plan_flops(plan, backend)
        if (
            self.policy is not None
            and self.policy.storage_only
            and not (_is_tracing(a) or _is_tracing(b))
        ):
            a, b = self.policy.replicated(a), self.policy.replicated(b)
        t0 = time.perf_counter()
        try:
            if backend in ("batched", "spmd"):
                out = getattr(self, f"_execute_{backend}")(
                    plan, a, b, a_mats=a_mats, b_mats=b_mats
                )
            else:
                out = getattr(self, f"_execute_{backend}")(plan, a, b)
        except Exception:
            if _is_tracing(a) or _is_tracing(b):
                raise  # mid-trace failure: the caller's eager fallback recovers
            out = self._degraded_call(backend, plan, a, b, axes)
        self.backend_seconds[backend] += time.perf_counter() - t0
        # spmd mode constrains output layout; storage mode leaves compute
        # results replicated — the sweep re-places what it actually stores
        if (
            self.policy is not None
            and not self.policy.storage_only
            and not _is_tracing(out)
        ):
            out = self.policy.place(out)
        return out

    # ------------------------------------------------------------ cost model
    def choose_backend(self, plan: ContractionPlan) -> str:
        # dense pays one GEMM over the padded full dims plus a per-block
        # dispatch for embedding/extraction (to_dense is .at[].set per block);
        # list pays per-pair GEMM dispatch; batched pays the exact list flops
        # but dispatches per unique operand block (matricize), per bucket
        # (stack + batched GEMM + segment-sum) and per output slot, all
        # cheaper than a GEMM dispatch; csr pays padding flops but a single
        # batched kernel.  All in equivalent flops.
        n_embed = plan.num_in_blocks + len(plan.out_keys)
        cost = {
            "list": plan.flops_list + self.pair_overhead * plan.num_pairs,
            "dense": plan.flops_dense + self.pair_overhead * n_embed,
        }
        if plan.num_pairs:
            L = plan.batched
            n_disp = 0.5 * L.num_unique + 2.0 * L.num_buckets + 0.25 * L.num_out_slots
            cost["batched"] = plan.flops_list + self.pair_overhead * n_disp
        if self.allow_csr and plan.num_pairs:
            cost["csr"] = plan.flops_csr + self.pair_overhead * plan.num_pairs * 0.25
        return min(cost, key=cost.get)

    @staticmethod
    def _plan_flops(plan: ContractionPlan, backend: str) -> float:
        if backend == "dense":
            return plan.flops_dense
        if backend == "csr":
            return plan.flops_csr if plan.num_pairs else 0.0
        # list, batched and spmd execute the exact pair flops (spmd's P/N
        # divisibility zero-padding adds no counted work)
        return plan.flops_list

    @property
    def _spmd_mode(self) -> bool:
        return self.policy is not None and self.policy.mode == "spmd"

    # ---------------------------------------------------- degradation ladder
    def _degraded_call(
        self,
        failed: str,
        plan: ContractionPlan,
        a: BlockSparseTensor,
        b: BlockSparseTensor,
        axes: Axes,
    ) -> BlockSparseTensor:
        """Retry a failed backend down ``CONTRACTION_LADDER`` to the seed.

        Every rung computes the same charge-conserving contraction (the
        backend-equality guarantee), so recovery changes wall time, never
        values.  The final rung is the seed ``tensor.blocksparse.contract``
        — plan-free, engine-free, the code path the whole dist layer is
        tested against.  Only reached eagerly; mid-trace failures re-raise.
        """
        self.note_retry("contraction")
        start = (
            CONTRACTION_LADDER.index(failed) + 1
            if failed in CONTRACTION_LADDER
            else 0
        )
        for rung in CONTRACTION_LADDER[start:]:
            if rung == "csr" and not self.allow_csr:
                continue
            if rung == "spmd" and not self._spmd_mode:
                continue
            try:
                out = getattr(self, f"_execute_{rung}")(plan, a, b)
            except Exception:
                continue
            self.note_degradation(f"contraction_{rung}")
            return out
        out = contract(a, b, axes)
        self.note_degradation("contraction_seed")
        return out

    # -------------------------------------------------------------- backends
    def _execute_list(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        out_blocks = execute_pairs(plan, a.blocks, b.blocks)
        return BlockSparseTensor(plan.out_indices, out_blocks, plan.out_charge)

    def _execute_dense(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        dense = jnp.tensordot(a.to_dense(), b.to_dense(), axes=(plan.ax_a, plan.ax_b))
        blocks = {k: dense[sl] for k, sl in plan.dense_out_slices()}
        return BlockSparseTensor(plan.out_indices, blocks, plan.out_charge)

    def _execute_batched(
        self,
        plan: ContractionPlan,
        a: BlockSparseTensor,
        b: BlockSparseTensor,
        *,
        a_mats=None,
        b_mats=None,
    ) -> BlockSparseTensor:
        return execute_batched(
            plan,
            a,
            b,
            a_mats=a_mats,
            b_mats=b_mats,
            use_kernel=self.use_kernel,
            interpret=self.interpret,
            mesh=self._mesh_key(),
        )

    def _execute_spmd(
        self,
        plan: ContractionPlan,
        a: BlockSparseTensor,
        b: BlockSparseTensor,
        *,
        a_mats=None,
        b_mats=None,
    ) -> BlockSparseTensor:
        """The batched bucket tables executed through the shard_map
        collective GEMM (dist/spmd.py): pairs over "row", output columns
        over "col", one psum + one all_gather per bucket."""
        return execute_batched(
            plan,
            a,
            b,
            a_mats=a_mats,
            b_mats=b_mats,
            mesh=self._mesh_key(),
            gemm_fn=spmd_mod.make_spmd_gemm(
                self.policy.mesh, self.policy.row_axis, self.policy.col_axis
            ),
        )

    def _mesh_key(self):
        return None if self.policy is None else self.policy.mesh

    def _execute_csr(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        if not plan.pairs:
            return BlockSparseTensor(plan.out_indices, {}, plan.out_charge)
        L = plan.csr
        lhs_all = pack_blocks(a, L.a_keys, plan.keep_a, plan.ax_a, L.bm, L.bk, True)
        rhs_all = pack_blocks(b, L.b_keys, plan.keep_b, plan.ax_b, L.bk, L.bn, False)
        li, ri, oi = memo_dev_idx(
            L,
            self._mesh_key(),
            _is_tracing(a) or _is_tracing(b),
            (L.li, L.ri, L.oi),
        )
        lhs = lhs_all[li]
        rhs = rhs_all[ri]
        out_padded = block_sparse_matmul(
            lhs,
            rhs,
            oi,
            len(L.out_keys),
            interpret=self.interpret,
            use_kernel=self.use_kernel,
        )
        out_blocks: Dict[BlockKey, jax.Array] = {}
        for o, (kc, (r, c)) in enumerate(zip(L.out_keys, L.out_rc)):
            out_blocks[kc] = out_padded[o, :r, :c].reshape(plan.out_block_shape(kc))
        return BlockSparseTensor(plan.out_indices, out_blocks, plan.out_charge)

    # ------------------------------------------------------- two-site matvec
    def two_site_matvec(
        self,
        A: BlockSparseTensor,
        Wj: BlockSparseTensor,
        Wj1: BlockSparseTensor,
        B: BlockSparseTensor,
        x: BlockSparseTensor,
        mats=None,
    ) -> BlockSparseTensor:
        """y = K x with K = A . W_j . W_{j+1} . B (paper Fig. 1d).

        ``mats`` optionally carries the pre-matricized fixed operands
        (A as lhs of step 1; W_j, W_{j+1}, B as rhs of steps 2-4), computed
        once per Davidson solve by ``matvec_fn`` instead of inside every
        call; only the batched backend consumes them.
        """
        mA, mWj, mWj1, mB = mats if mats is not None else (None,) * 4
        t = self(A, x, ((2,), (0,)), a_mats=mA)
        t = self(t, Wj, ((1, 2), (0, 2)), b_mats=mWj)
        t = self(t, Wj1, ((4, 1), (0, 2)), b_mats=mWj1)
        t = self(t, B, ((4, 1), (1, 2)), b_mats=mB)
        return t

    def _fixed_operand_mats(self, A, Wj, Wj1, B):
        """Matricized fixed Davidson operands for the batched backend.

        The matricization axes are static per matvec step (A contracts its
        mode 2 in step 1; W_j / W_{j+1} contract modes (0, 2); B contracts
        modes (1, 2)), so these 2-D forms never depend on x's structure.
        """
        return (
            matricize_lhs(A, (0, 1), (2,)),
            matricize_rhs(Wj, (1, 3), (0, 2)),
            matricize_rhs(Wj1, (1, 3), (0, 2)),
            matricize_rhs(B, (0,), (1, 2)),
        )

    def matvec_fn(
        self,
        A: BlockSparseTensor,
        Wj: BlockSparseTensor,
        Wj1: BlockSparseTensor,
        B: BlockSparseTensor,
        jit: bool = False,
    ) -> Callable[[BlockSparseTensor], BlockSparseTensor]:
        """Davidson matvec closure; with ``jit=True`` the planned pipeline is
        compiled once per block structure (plan metadata is static aux)."""
        if self.policy is not None and self.policy.storage_only:
            # gather the fixed operands once, not on every Davidson iteration
            A = self.policy.replicated(A)
            Wj = self.policy.replicated(Wj)
            Wj1 = self.policy.replicated(Wj1)
            B = self.policy.replicated(B)
        # "auto" may route any matvec step to the batched backend, and spmd
        # mode routes every step through the bucketed spmd GEMM, so both
        # precompute the fixed-operand mats (unused steps ignore them)
        mats = (
            self._fixed_operand_mats(A, Wj, Wj1, B)
            if self.backend in ("batched", "auto") or self._spmd_mode
            else None
        )
        if not jit:
            return lambda x: self.two_site_matvec(A, Wj, Wj1, B, x, mats=mats)
        if self._jit_mv is None:

            def _traced(A_, Wj_, Wj1_, B_, mats_, x_):
                self.jit_retraces += 1  # body runs only when jax (re)traces
                return self.two_site_matvec(A_, Wj_, Wj1_, B_, x_, mats=mats_)

            self._jit_mv = jax.jit(_traced)
        store = persist.active_store()
        if store is None or self.policy is not None:
            # no store (or mesh-placed operands, whose shardings must not be
            # baked into a portable artifact): the plain jitted path
            return lambda x: self._jit_mv(A, Wj, Wj1, B, mats, x)
        return self._exported_matvec(store, A, Wj, Wj1, B, mats)

    def _exported_matvec(self, store, A, Wj, Wj1, B, mats):
        """Matvec closure backed by the persistent export store.

        The matvec is the dominant cold-start cost: every padded structure
        traces the whole planned pipeline through Python and lowers it to
        StableHLO even when the XLA *compile* hits the persistent cache.  A
        primed store replays the exported StableHLO directly — no re-trace,
        no re-lower.  The exported body takes the fixed-operand mats as
        positional tuples (their dict form, keyed by block keys, is not a
        serializable treedef) with the key lists folded in as statics; x's
        structure keys the per-solve memo because Davidson solves at
        different sites share this engine's ``_jit_mv`` but not avals.
        A missing entry exports best-effort and falls back to ``_jit_mv``.
        """
        engine = self
        mat_keys = mats_vals = None
        if mats is not None:
            mat_keys = tuple(tuple(sorted(d)) for d in mats)
            mats_vals = tuple(
                tuple(d[k] for k in ks) for d, ks in zip(mats, mat_keys)
            )

        def _export_body(A_, Wj_, Wj1_, B_, mv_, x_):
            mats_ = (
                tuple(dict(zip(ks, vs)) for ks, vs in zip(mat_keys, mv_))
                if mv_ is not None
                else None
            )
            return engine.two_site_matvec(A_, Wj_, Wj1_, B_, x_, mats=mats_)

        ops_sig = tuple(
            (t.indices, t.charge, tuple(sorted(t.blocks)))
            for t in (A, Wj, Wj1, B)
        )
        conf = (self.backend, self.use_kernel, self.interpret, self.allow_csr)

        def call(x):
            if any(isinstance(b, jax.core.Tracer) for b in x.blocks.values()):
                # deserialized artifacts are opaque executables and cannot
                # be traced through (e.g. an outer vmap/jit over the solve)
                return engine._jit_mv(A, Wj, Wj1, B, mats, x)
            xsig = (x.indices, x.charge, tuple(sorted(x.blocks)))
            ekey = ("matvec", conf, ops_sig, xsig)
            fn = self._export_mv.get(ekey)
            if fn is None:
                args = (A, Wj, Wj1, B, mats_vals, x)
                fn = store.load_export(ekey, args)
                if fn is None:
                    store.save_export(ekey, _export_body, args)
                    fn = False  # remembered: this structure has no artifact
                self._export_mv[ekey] = fn
            if fn is False:
                return engine._jit_mv(A, Wj, Wj1, B, mats, x)
            return fn(A, Wj, Wj1, B, mats_vals, x)

        return call

    # ------------------------------------------------------------ decomp API
    def svd_split(
        self,
        theta: BlockSparseTensor,
        n_row_modes: int,
        max_bond: int,
        cutoff: float = 1e-12,
        absorb: str = "right",
    ):
        """Planned blockwise truncated SVD through the decomposition engine.

        Same signature and return value as the seed
        ``tensor.blocksparse.svd_split_unplanned`` and the same <1e-10
        equality guarantee (up to per-singular-vector sign gauge) as
        ``dist.decomp``; sharded inputs are gathered to replicated form
        first under a storage-mode policy, like contraction operands.
        """
        if (
            self.policy is not None
            and self.policy.storage_only
            and not _is_tracing(theta)
        ):
            theta = self.policy.replicated(theta)
        U, V, svals, err = self.decomp.svd_split(
            theta, n_row_modes, max_bond, cutoff=cutoff, absorb=absorb
        )
        if self.policy is not None and not self.policy.storage_only:
            U, V = self.policy.place(U), self.policy.place(V)
        return U, V, svals, err

    # --------------------------------------------------------------- env API
    def env_update_left(
        self,
        A: BlockSparseTensor,
        T: BlockSparseTensor,
        W: BlockSparseTensor,
        *,
        mpo_padded: Optional[BlockSparseTensor] = None,
    ) -> BlockSparseTensor:
        """Planned fused left env update through the environment engine.

        Same result as the seed ``core.env.extend_left(A, T, W)`` to <1e-10
        block-for-block (``dist.envcore``), executed as one compiled call;
        sharded inputs are gathered to replicated form first under a
        storage-mode policy, and the output is placed under an spmd policy,
        like contraction results.
        """
        return self._env_update("left", A, T, W, mpo_padded)

    def env_update_right(
        self,
        B: BlockSparseTensor,
        T: BlockSparseTensor,
        W: BlockSparseTensor,
        *,
        mpo_padded: Optional[BlockSparseTensor] = None,
    ) -> BlockSparseTensor:
        """Planned fused right env update; see ``env_update_left``."""
        return self._env_update("right", B, T, W, mpo_padded)

    def _env_update(self, side, env, T, W, mpo_padded):
        if (
            self.policy is not None
            and self.policy.storage_only
            and not (_is_tracing(env) or _is_tracing(T))
        ):
            env, T, W = (
                self.policy.replicated(env),
                self.policy.replicated(T),
                self.policy.replicated(W),
            )
            if mpo_padded is not None:
                # keep the caller's per-site padded-MPO cache: gathering the
                # padded form is cheaper than re-padding the gathered W on
                # every one of the 2(n-1) updates per sweep
                mpo_padded = self.policy.replicated(mpo_padded)
        fn = self.env.update_left if side == "left" else self.env.update_right
        out = fn(
            env,
            T,
            W,
            mpo_padded=mpo_padded,
            # spmd mode: the fused core's three contractions run as shard_map
            # bucket GEMMs on the policy mesh (envcore builds/caches the
            # spmd variant of the core per mesh)
            spmd_mesh=self.policy.mesh if self._spmd_mode else None,
        )
        if (
            self.policy is not None
            and not self.policy.storage_only
            and not _is_tracing(out)
        ):
            out = self.policy.place(out)
        return out

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict:
        """Plan-cache, backend-dispatch, flop, wall-time and retrace counters.

        ``backend_counts`` / ``backend_flops`` increment when ``__call__``
        runs, i.e. at trace time under a jitted matvec — compiled replays
        bypass Python, so with ``jit_matvec=True`` they reflect unique traced
        structures, not total executed contractions.  ``backend_seconds`` is
        host-side dispatch time in seconds (jax is async; it excludes device
        queue drain, and under tracing it measures trace time).
        ``jit_retraces`` counts how many times the jitted matvec was
        (re)traced — the compile-time side of the ledger, vs steady-state
        replays.  ``decomp`` is the decomposition-stage sub-ledger (SVD
        calls/flops/seconds/retraces; see ``DecompositionEngine.stats``) and
        ``env`` the environment-stage one (fused update count/flops/wall/
        retraces; see ``EnvironmentEngine.stats``) — together with the
        contraction counters they give the per-stage split that
        ``benchmarks/bench_dist.py`` reports.

        ``retries`` / ``degradations`` are the degradation-ladder ledger
        (DESIGN.md 3.8): stage-keyed counts of failed first attempts and the
        ladder rung that recovered them (e.g. ``contraction_list``,
        ``env_seed``, ``pair_seed``).  Both empty on a healthy run — the
        clean tier-1 bench leg asserts exactly that.
        """
        return {
            "plan_cache": self.cache.stats(),
            "backend_counts": dict(self.backend_counts),
            "backend_flops": dict(self.backend_flops),
            "backend_seconds": dict(self.backend_seconds),
            "jit_retraces": self.jit_retraces,
            "retries": dict(self.retries),
            "degradations": dict(self.degradations),
            "decomp": self.decomp.stats(),
            "env": self.env.stats(),
            # process-wide SPMD collective ledger (dist/spmd.py): gemm
            # calls, fallbacks, traced psum/all_gather counts.  Module-level
            # because compiled SPMD programs are shared across engines.
            "spmd": spmd_mod.stats(),
        }
