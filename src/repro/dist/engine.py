"""ContractionEngine: plan-cached, mesh-sharded block-sparse contraction.

The engine is a drop-in replacement for the bare ``contract_fn`` threaded
through ``core/env.py`` / ``core/sweep.py``: it is callable as
``engine(a, b, axes)`` and returns a ``BlockSparseTensor``.  Per call it

1. fetches (or builds) the ``ContractionPlan`` for the contraction's
   structural signature from a ``PlanCache``, skipping the per-call hash
   join / charge bookkeeping the seed algorithms re-derive every time;
2. picks a backend — "list" (one tensordot per block pair), "dense" (embed +
   one GEMM), or "csr" (padded batched block GEMM) — either fixed or by a
   flop-and-padding cost model ("auto").  "auto" chooses between list and
   dense; csr joins the auto candidate set only with ``allow_csr=True``,
   since without a real Pallas target (TPU) the csr execution path is not
   wall-time competitive however favorable its padded-flop count looks;
3. executes the plan and, when a ``BlockShardPolicy`` is attached, places the
   output blocks on the device mesh (outside jit; under tracing XLA owns
   layout).

``two_site_matvec`` is the planned Davidson matvec of paper Fig. 1d;
``matvec_fn`` optionally jits it.  Because ``BlockSparseTensor`` is a pytree
whose aux data (indices, charge, block keys) is static, jax's own trace cache
keys compiled executables by block structure, so repeated sweeps at the same
bond dimensions reuse both the plans and the compiled matvec.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.block_gemm.ops import block_sparse_matmul
from ..tensor.block_csr import pack_blocks
from ..tensor.blocksparse import BlockKey, BlockSparseTensor
from .plan import Axes, ContractionPlan, PlanCache, global_plan_cache
from .shard import BlockShardPolicy

# cost-model overhead charged per dispatched block GEMM, in equivalent flops:
# on small DMRG blocks the per-op dispatch dominates, which is exactly why the
# paper's dense algorithm wins at small m (their Fig. 5 crossover).
PAIR_OVERHEAD_FLOPS = 16384.0


def _is_tracing(t: BlockSparseTensor) -> bool:
    return any(isinstance(b, jax.core.Tracer) for b in t.blocks.values())


class ContractionEngine:
    """Executes cached ContractionPlans through a pluggable backend."""

    def __init__(
        self,
        backend: str = "auto",
        cache: Optional[PlanCache] = None,
        policy: Optional[BlockShardPolicy] = None,
        *,
        use_kernel: bool = False,
        interpret: bool = False,  # compiled Pallas by default, like block_csr
        allow_csr: bool = False,
        pair_overhead: float = PAIR_OVERHEAD_FLOPS,
    ):
        assert backend in ("auto", "list", "dense", "csr")
        self.backend = backend
        self.cache = cache if cache is not None else global_plan_cache
        self.policy = policy
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.allow_csr = allow_csr
        self.pair_overhead = pair_overhead
        self.backend_counts: Dict[str, int] = {"list": 0, "dense": 0, "csr": 0}
        self._jit_mv = None

    # ----------------------------------------------------------------- entry
    def __call__(
        self, a: BlockSparseTensor, b: BlockSparseTensor, axes: Axes
    ) -> BlockSparseTensor:
        plan = self.cache.get(a, b, axes)
        backend = self.backend if self.backend != "auto" else self.choose_backend(plan)
        self.backend_counts[backend] += 1
        if (
            self.policy is not None
            and self.policy.storage_only
            and not (_is_tracing(a) or _is_tracing(b))
        ):
            a, b = self.policy.replicated(a), self.policy.replicated(b)
        out = getattr(self, f"_execute_{backend}")(plan, a, b)
        # spmd mode constrains output layout; storage mode leaves compute
        # results replicated — the sweep re-places what it actually stores
        if (
            self.policy is not None
            and not self.policy.storage_only
            and not _is_tracing(out)
        ):
            out = self.policy.place(out)
        return out

    # ------------------------------------------------------------ cost model
    def choose_backend(self, plan: ContractionPlan) -> str:
        # dense pays one GEMM over the padded full dims plus a per-block
        # dispatch for embedding/extraction (to_dense is .at[].set per block);
        # list pays per-pair GEMM dispatch; csr pays padding flops but a
        # single batched kernel.  All in equivalent flops.
        n_embed = plan.num_in_blocks + len(plan.out_keys)
        cost = {
            "list": plan.flops_list + self.pair_overhead * plan.num_pairs,
            "dense": plan.flops_dense + self.pair_overhead * n_embed,
        }
        if self.allow_csr and plan.num_pairs:
            cost["csr"] = plan.flops_csr + self.pair_overhead * plan.num_pairs * 0.25
        return min(cost, key=cost.get)

    # -------------------------------------------------------------- backends
    def _execute_list(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        ax = (plan.ax_a, plan.ax_b)
        out_blocks: Dict[BlockKey, jax.Array] = {}
        for ka, kb, kc in plan.pairs:
            piece = jnp.tensordot(a.blocks[ka], b.blocks[kb], axes=ax)
            if kc in out_blocks:
                out_blocks[kc] = out_blocks[kc] + piece
            else:
                out_blocks[kc] = piece
        return BlockSparseTensor(plan.out_indices, out_blocks, plan.out_charge)

    def _execute_dense(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        dense = jnp.tensordot(a.to_dense(), b.to_dense(), axes=(plan.ax_a, plan.ax_b))
        blocks = {k: dense[sl] for k, sl in plan.dense_out_slices()}
        return BlockSparseTensor(plan.out_indices, blocks, plan.out_charge)

    def _execute_csr(
        self, plan: ContractionPlan, a: BlockSparseTensor, b: BlockSparseTensor
    ) -> BlockSparseTensor:
        if not plan.pairs:
            return BlockSparseTensor(plan.out_indices, {}, plan.out_charge)
        L = plan.csr
        lhs_all = pack_blocks(a, L.a_keys, plan.keep_a, plan.ax_a, L.bm, L.bk, True)
        rhs_all = pack_blocks(b, L.b_keys, plan.keep_b, plan.ax_b, L.bk, L.bn, False)
        if L.dev_idx is None:  # transfer the static index tables once per plan
            L.dev_idx = (jnp.asarray(L.li), jnp.asarray(L.ri), jnp.asarray(L.oi))
        li, ri, oi = L.dev_idx
        lhs = lhs_all[li]
        rhs = rhs_all[ri]
        out_padded = block_sparse_matmul(
            lhs,
            rhs,
            oi,
            len(L.out_keys),
            interpret=self.interpret,
            use_kernel=self.use_kernel,
        )
        out_blocks: Dict[BlockKey, jax.Array] = {}
        for o, (kc, (r, c)) in enumerate(zip(L.out_keys, L.out_rc)):
            out_blocks[kc] = out_padded[o, :r, :c].reshape(plan.out_block_shape(kc))
        return BlockSparseTensor(plan.out_indices, out_blocks, plan.out_charge)

    # ------------------------------------------------------- two-site matvec
    def two_site_matvec(
        self,
        A: BlockSparseTensor,
        Wj: BlockSparseTensor,
        Wj1: BlockSparseTensor,
        B: BlockSparseTensor,
        x: BlockSparseTensor,
    ) -> BlockSparseTensor:
        """y = K x with K = A . W_j . W_{j+1} . B (paper Fig. 1d)."""
        t = self(A, x, ((2,), (0,)))
        t = self(t, Wj, ((1, 2), (0, 2)))
        t = self(t, Wj1, ((4, 1), (0, 2)))
        t = self(t, B, ((4, 1), (1, 2)))
        return t

    def matvec_fn(
        self,
        A: BlockSparseTensor,
        Wj: BlockSparseTensor,
        Wj1: BlockSparseTensor,
        B: BlockSparseTensor,
        jit: bool = False,
    ) -> Callable[[BlockSparseTensor], BlockSparseTensor]:
        """Davidson matvec closure; with ``jit=True`` the planned pipeline is
        compiled once per block structure (plan metadata is static aux)."""
        if self.policy is not None and self.policy.storage_only:
            # gather the fixed operands once, not on every Davidson iteration
            A = self.policy.replicated(A)
            Wj = self.policy.replicated(Wj)
            Wj1 = self.policy.replicated(Wj1)
            B = self.policy.replicated(B)
        if not jit:
            return lambda x: self.two_site_matvec(A, Wj, Wj1, B, x)
        if self._jit_mv is None:
            self._jit_mv = jax.jit(
                lambda A_, Wj_, Wj1_, B_, x_: self.two_site_matvec(
                    A_, Wj_, Wj1_, B_, x_
                )
            )
        return lambda x: self._jit_mv(A, Wj, Wj1, B, x)

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict:
        """Plan-cache and backend-dispatch counters.

        Counters increment when ``__call__`` runs, i.e. at trace time under
        a jitted matvec — compiled replays bypass Python, so with
        ``jit_matvec=True`` the counts reflect unique traced structures, not
        total executed contractions.
        """
        return {"plan_cache": self.cache.stats(), "backend_counts": dict(self.backend_counts)}
