"""Environment engine: plan-cached fused left/right env updates.

The environment stage (paper Fig. 1d, Sec. II-C) absorbs one site into the
left or right environment after every pair optimization — three chained
block-sparse contractions per site per half-sweep, plus a full right-to-left
rebuild at startup.  The seed ``extend_left`` / ``extend_right`` issue those
three contractions as separate eager calls: each pays a host-side plan
lookup, a per-pair GEMM dispatch fan-out, and materializes its intermediate
before the next call starts.  After PRs 1-3 industrialized the matvec and
the SVD split, this was the last uncompiled cost center of the sweep.

This module brings it under the plan/execute architecture, mirroring
``dist/decomp.py``:

1. An ``EnvironmentPlan`` (``dist/plan.py``, cached by the composite
   structural signature of the (env, site, MPO) triple + sweep direction)
   chains the three per-site ``ContractionPlan``s — fetched from the shared
   contraction ``PlanCache`` — and resolves every intermediate block
   structure ahead of time, including the bra (conjugate) structure and the
   final transpose.
2. ``EnvironmentEngine.update_left/right`` executes the plan as ONE fused
   jit-compiled core: all three contractions, the conjugation and the
   transpose trace into a single XLA program with no host round-trips
   between them — intermediates never materialize as Python-side tensors.
3. Operands are power-of-two padded first (``pad_block_sparse``, the same
   compile-once trick as the bucketed matvec): zero-padding is exact for
   contractions, and it quantizes the traced structure so the core compiles
   once per *bucketed* structure instead of once per site per sweep.  The
   result is sliced back to the true (unpadded) env structure, which is
   derived directly from the site/MPO indices.

Backend-equality guarantee: the fused core computes exactly the seed
three-contraction pipeline (same pair tables, list-order accumulation
within each step), so its output matches ``extend_left`` / ``extend_right``
block-for-block to <1e-10 on all backends (tests/test_env.py; DMRG
energies with ``jit_env=True`` equal seed to <1e-10).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..tensor.blocksparse import BlockSparseTensor
from ..tensor.qn import Index
from . import faults, persist
from .batch import execute_pairs, pad_block_sparse, unpad_block_sparse
from .faults import FaultInjected
from .plan import (
    EnvPlanCache,
    EnvironmentPlan,
    global_env_cache,
)


def env_out_indices(
    site: BlockSparseTensor, mpo: BlockSparseTensor, side: str
) -> Tuple[Index, ...]:
    """The (i', k', l') structure an env update produces, from operands alone.

    Left update: the new env bonds are the site tensor's *right* index (bra
    side dualized) and the MPO's right bond; right update symmetrically uses
    the left indices.  Used to slice the padded fused-core output back to
    the true structure — two different unpadded triples may share one padded
    plan, so the unpadded target cannot live on the plan.
    """
    if side == "left":
        return (site.indices[2].dual(), mpo.indices[3], site.indices[2])
    return (site.indices[0].dual(), mpo.indices[0], site.indices[0])


def env_core_body(plan: EnvironmentPlan):
    """All three contractions + conj + transpose, one traceable function.

    Module-level (like ``decomp.svd_core_body``) so the engine's jitted
    wrapper and the ``jax.export`` persistence path (dist/persist.py) trace
    the identical body.  Input: the (padded) env/site/MPO block arrays in
    the plan's sorted key order; output: env blocks in ``plan.out_keys``
    order.  Plan metadata folds into the trace as constants.
    """
    p1, p2, p3 = plan.steps
    left = plan.side == "left"
    perm = plan.perm

    def body(env_blocks, site_blocks, mpo_blocks):
        e = dict(zip(plan.env_keys, env_blocks))
        t = dict(zip(plan.site_keys, site_blocks))
        w = dict(zip(plan.mpo_keys, mpo_blocks))
        bra = {k: jnp.conj(v) for k, v in t.items()}
        if left:
            x = execute_pairs(p1, e, t)
            x = execute_pairs(p2, x, w)
            x = execute_pairs(p3, bra, x)
        else:
            x = execute_pairs(p1, t, e)
            x = execute_pairs(p2, x, w)
            x = execute_pairs(p3, x, bra)
        return tuple(jnp.transpose(x[k], perm) for k in plan.pre_out_keys)

    return body


class EnvironmentEngine:
    """Executes cached EnvironmentPlans as fused jitted env updates.

    Parameters
    ----------
    cache: ``EnvPlanCache`` (defaults to the global one, shared with any
        other engine — plans and their compiled cores are reused).
    jit: compile the fused three-contraction core once per padded structure
        (default); ``False`` runs the same fused body eagerly, for debugging.
    pad: power-of-two-pad the operands before planning (default).  Padding
        is exact (padded operator entries are zero) and quantizes the traced
        structure — without it every bond-sector drift during convergence
        retraces the core.

    ``stats()`` reports cumulative counters; see its docstring for units.
    """

    def __init__(
        self,
        cache: Optional[EnvPlanCache] = None,
        *,
        jit: bool = True,
        pad: bool = True,
    ):
        self.cache = cache if cache is not None else global_env_cache
        self.jit = jit
        self.pad = pad
        self.env_updates = 0
        self.env_flops = 0.0
        self.env_seconds = 0.0
        self.jit_retraces = 0

    # ------------------------------------------------------------- jit core
    def _build_core(self, plan: EnvironmentPlan, body=None):
        """Compile (or wrap eagerly) the shared ``env_core_body``.

        One compiled executable per padded block structure — plan metadata
        folds into the trace as constants.  ``body`` overrides the traced
        body (the spmd variant passes ``spmd.spmd_env_core_body``).
        """
        engine = self
        if body is None:
            body = env_core_body(plan)
        if not self.jit:
            return body

        def traced(env_blocks, site_blocks, mpo_blocks):
            engine.jit_retraces += 1  # body runs only when jax (re)traces
            return body(env_blocks, site_blocks, mpo_blocks)

        return jax.jit(traced)

    # ----------------------------------------------------------------- entry
    def update_left(
        self,
        A: BlockSparseTensor,
        T: BlockSparseTensor,
        W: BlockSparseTensor,
        *,
        mpo_padded: Optional[BlockSparseTensor] = None,
        spmd_mesh=None,
    ) -> BlockSparseTensor:
        """A' = A · T · W · conj(T): absorb site T into the left env.

        ``spmd_mesh`` (a ("row","col") mesh) switches the fused core to the
        shard_map-collective variant (``dist/spmd.py``): same plan, same
        three contractions, bucket GEMMs partitioned over the mesh, fused
        into one compiled core (safe because the bucket programs keep
        replicated shard_map boundaries; see ``_update``).
        """
        return self._update("left", A, T, W, mpo_padded, spmd_mesh)

    def update_right(
        self,
        B: BlockSparseTensor,
        T: BlockSparseTensor,
        W: BlockSparseTensor,
        *,
        mpo_padded: Optional[BlockSparseTensor] = None,
        spmd_mesh=None,
    ) -> BlockSparseTensor:
        """B' = T · W · conj(T) · B: absorb site T into the right env."""
        return self._update("right", B, T, W, mpo_padded, spmd_mesh)

    def _update(self, side, env, T, W, mpo_padded=None, spmd_mesh=None):
        # fault point: exception out of the fused env core, standing in for
        # a compilation/launch failure of the jitted program.  Raised before
        # any work so the caller's seed-extend fallback sees a clean slate.
        if faults.fire("env.exception") is not None:
            raise FaultInjected("env.exception", "fused env core failed")
        t0 = time.perf_counter()
        if self.pad:
            # the MPO is immutable for a run, so callers (the sweep) may pass
            # its padded form once instead of re-padding every site visit
            env_p = pad_block_sparse(env)
            T_p = pad_block_sparse(T)
            W_p = mpo_padded if mpo_padded is not None else pad_block_sparse(W)
        else:
            env_p, T_p, W_p = env, T, W
        plan = self.cache.get(env_p, T_p, W_p, side)
        args = (
            tuple(env_p.blocks[k] for k in plan.env_keys),
            tuple(T_p.blocks[k] for k in plan.site_keys),
            tuple(W_p.blocks[k] for k in plan.mpo_keys),
        )
        # export round-trip (dist/persist.py), mirroring the decomp engine:
        # primed store -> replay StableHLO, no Python re-trace; cold run
        # with store -> export what was built (best-effort).  Deserialized
        # artifacts are opaque executables, so the path is skipped entirely
        # when the operands are tracers (the stacked serve pipeline vmaps
        # through this engine) — only the traceable built core can inline.
        core = None
        tracing = any(
            isinstance(x, jax.core.Tracer) for xs in args for x in xs
        )
        if spmd_mesh is not None:
            # spmd cores close over a live mesh (shard_map) — never
            # exportable, cached per mesh so globally shared plans don't
            # replay one mesh's program under another.  Jitting the fused
            # core over the inlined shard_map programs is safe ONLY because
            # the bucket GEMMs keep replicated boundaries (dist/spmd.py):
            # sharded shard_map in_specs under an enclosing jit trigger the
            # XLA partitioner's rematerialization path, which corrupts
            # values on CPU meshes (16x inflation observed).
            from .spmd import spmd_env_core_body

            key = ("spmd", spmd_mesh, self.jit)
            core = plan._exec.get(key)
            if core is None:
                core = self._build_core(
                    plan, body=spmd_env_core_body(plan, spmd_mesh)
                )
                plan._exec[key] = core
            blocks = core(*args)
            out = BlockSparseTensor(
                plan.out_indices, dict(zip(plan.out_keys, blocks)), plan.out_charge
            )
            if self.pad:
                out = unpad_block_sparse(out, env_out_indices(T, W, side))
            self.env_updates += 1
            self.env_flops += plan.flops
            self.env_seconds += time.perf_counter() - t0
            return out
        store = persist.active_store() if self.jit and not tracing else None
        if store is not None:
            core = plan._exec.get("export")
            if core is None:
                ekey = ("env_core", plan.signature)
                core = store.load_export(ekey, args)
                if core is None:
                    store.save_export(ekey, env_core_body(plan), args)
                    core = False  # remembered: no artifact for this plan
                plan._exec["export"] = core
            if core is False:
                core = None
        if core is None:
            core = plan._exec.get(self.jit)
            if core is None:
                core = self._build_core(plan)
                plan._exec[self.jit] = core
        blocks = core(*args)
        out = BlockSparseTensor(
            plan.out_indices, dict(zip(plan.out_keys, blocks)), plan.out_charge
        )
        if self.pad:
            out = unpad_block_sparse(out, env_out_indices(T, W, side))
        self.env_updates += 1
        self.env_flops += plan.flops
        self.env_seconds += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------- reporting
    def stats(self) -> Dict:
        """Cumulative environment-stage counters.

        - ``plan_cache``: hits/misses/size of the EnvPlanCache.
        - ``env_updates``: number of fused left/right updates executed.
        - ``env_flops``: summed pair-table flops of the executed plans —
          counted on the *padded* structure (what actually runs), a
          cost-model estimate, not a hardware counter.
        - ``env_seconds``: host wall-clock per update (pad + plan lookup +
          fused-call dispatch + unpad).  Jax is async, so like the
          contraction engine's ``backend_seconds`` this excludes device
          queue drain.
        - ``jit_retraces``: times the fused core was (re)traced; with
          padding on, this stops growing at structural steady state
          (compile-once).  Cores are cached on the globally shared plan, so
          a trace is attributed to the engine that first compiled it.
        """
        return {
            "plan_cache": self.cache.stats(),
            "env_updates": self.env_updates,
            "env_flops": self.env_flops,
            "env_seconds": self.env_seconds,
            "jit_retraces": self.jit_retraces,
        }


# Shared default engine (module-level so plans and compiled cores persist
# across calls); sweep-owned ContractionEngines carry their own
# EnvironmentEngine for per-run stats.
default_env_engine = EnvironmentEngine()
