"""RWKV6 (Finch) 3B — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 d_ff=8960 vocab=65536; head_size 64 => 40 time-mix heads.
Sub-quadratic (linear-time recurrence) => runs the long_500k shape.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="rwkv6_3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,          # time-mix heads = d_model / rwkv_head_dim
        n_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_dim=64,
        sub_quadratic=True,
    )
)
