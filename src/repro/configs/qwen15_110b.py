"""Qwen1.5-110B [hf:Qwen/Qwen1.5-110B family]: QKV bias, GQA.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen15_110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1000000.0,
    )
)
