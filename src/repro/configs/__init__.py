"""Arch configs (10 assigned architectures + the paper's own DMRG systems)."""
from .base import ARCH_IDS, SHAPES, ArchConfig, all_configs, get_config

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "all_configs", "get_config"]
