"""Architecture config schema + registry for the 10 assigned architectures.

Every assigned arch gets one ``configs/<id>.py`` exporting ``CONFIG``; the
registry resolves ``--arch <id>``.  ``smoke()`` derives the reduced-size
variant used by per-arch CPU smoke tests (full configs are only ever lowered
via ShapeDtypeStructs in the dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = [
    "rwkv6_3b",
    "codeqwen15_7b",
    "qwen15_110b",
    "llama3_8b",
    "granite_3_2b",
    "pixtral_12b",
    "whisper_tiny",
    "qwen2_moe_a27b",
    "moonshot_v1_16b_a3b",
    "recurrentgemma_2b",
]

# canonical input shapes for LM-family archs (seq_len, global_batch)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (recurrentgemma): layer pattern, repeated; local attn window
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    local_window: int = 0
    d_rnn: int = 0                        # RG-LRU recurrent width
    conv_width: int = 4
    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq_len: int = 0                  # stub frontend output length
    # --- vlm (pixtral) ---
    n_patches: int = 0                    # stub patch embeddings per image
    # --- capability flags ---
    sub_quadratic: bool = False           # eligible for long_500k
    has_decoder: bool = True              # encoder-only archs skip decode
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""              # "" = model dtype; "int8" quantizes

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def n_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included once)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "ssm":  # rwkv6 time-mix ~ 5 square mats + loras
            attn = 5 * d * d
        ffn = 3 * d * self.d_ff
        if self.n_experts:
            ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.n_shared_experts:
                ffn += 3 * d * self.moe_d_ff * self.n_shared_experts
        per_layer = attn + ffn
        if self.block_pattern:
            n_attn = sum(1 for _ in range(L) if self._layer_kind(_) == "attn")
            n_rec = L - n_attn
            rec = 3 * d * self.d_rnn + self.d_rnn * self.conv_width + 2 * self.d_rnn
            per = n_attn * (attn + ffn) + n_rec * (rec + ffn)
            return per + 2 * self.vocab_size * d
        total = L * per_layer + self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.n_enc_layers:
            total += self.n_enc_layers * per_layer + L * (attn + d * d)  # cross-attn
        return total

    def active_param_count(self) -> int:
        if not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        ffn = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff + d * self.n_experts
        return L * (attn + ffn) + self.vocab_size * d * 2

    def _layer_kind(self, i: int) -> str:
        if not self.block_pattern:
            return "attn"
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self._layer_kind(i) for i in range(self.n_layers))

    def shape_supported(self, shape_name: str) -> Tuple[bool, str]:
        kind = SHAPES[shape_name]["kind"]
        if kind == "decode" and not self.has_decoder:
            return False, "encoder-only arch has no decode step"
        if shape_name == "long_500k" and not self.sub_quadratic:
            return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
        return True, ""

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat = self.block_pattern
        n_layers = len(pat) if pat else 2
        return dataclasses.replace(
            self,
            n_layers=max(n_layers, 2 if not pat else len(pat)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 // max(1, self.n_rep)),
            head_dim=16,
            d_ff=96,
            vocab_size=128,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=32 if self.n_experts else 0,
            capacity_factor=8.0,  # dropless at test sizes

            n_shared_experts=min(self.n_shared_experts, 1),
            d_rnn=64 if self.d_rnn else 0,
            local_window=16 if self.local_window else 0,
            rwkv_head_dim=16,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq_len=24 if self.enc_seq_len else 0,
            n_patches=8 if self.n_patches else 0,
            dtype="float32",
        )


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    name = name.replace("-", "_").replace(".", "")
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")
    return _REGISTRY[name]


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
