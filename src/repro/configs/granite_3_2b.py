"""Granite-3.0 2B base [hf:ibm-granite/granite-3.0-2b-base]: GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155, tied embeddings.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="granite_3_2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=49155,
        tie_embeddings=True,
        rope_theta=10000.0,
    )
)
