"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed top-4 + shared.

24L d_model=2048 16H (kv=16) routed-expert d_ff=1408, 60 experts top-4,
4 shared experts (shared intermediate 4*1408=5632), vocab=151936.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2_moe_a27b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5632,             # shared-expert path width
        vocab_size=151936,
        qkv_bias=True,
        n_experts=60,
        top_k=4,
        moe_d_ff=1408,
        n_shared_experts=4,
        rope_theta=1000000.0,
    )
)
