"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder, conv frontend stubbed.

4 encoder + 4 decoder layers, d_model=384 6H d_ff=1536 vocab=51865.
input_specs() provides precomputed frame embeddings [B, 1500, 384] (the
conv1d+GELU frontend output), per the assignment's modality-stub rule.
Full attention (quadratic) => long_500k skipped.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="whisper_tiny",
        family="audio",
        n_layers=4,            # decoder layers
        n_enc_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        enc_seq_len=1500,
        tie_embeddings=True,
        rope_theta=0.0,        # whisper uses learned/sinusoidal positions
    )
)
