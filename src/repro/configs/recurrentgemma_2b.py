"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU + local attn, 1:2.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
pattern (rglru, rglru, attn), local attention window 2048, d_rnn=2560.
Sub-quadratic => runs the long_500k shape.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="recurrentgemma_2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn"),
        local_window=2048,
        d_rnn=2560,
        conv_width=4,
        tie_embeddings=True,
        sub_quadratic=True,
        rope_theta=10000.0,
    )
)
