"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 64 experts top-6.

48L d_model=2048 16H (kv=16) expert d_ff=1408, 64 routed top-6 + 2 shared,
vocab=163840.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="moonshot_v1_16b_a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,             # shared-expert path width (2 x 1408)
        vocab_size=163840,
        n_experts=64,
        top_k=6,
        moe_d_ff=1408,
        n_shared_experts=2,
        rope_theta=50000.0,
    )
)
