"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: mistral-nemo backbone + ViT.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=160.
The pixtral-ViT frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings [B, n_patches, d_model] prepended to the text.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="pixtral_12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=160,
        d_ff=14336,
        vocab_size=131072,
        n_patches=256,
        rope_theta=1000000000.0,
    )
)
