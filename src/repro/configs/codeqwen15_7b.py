"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: qwen1.5 arch, QKV bias.

32L d_model=4096 32H (GQA kv=32 => MHA) d_ff=13440 vocab=92416.
"""
from .base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="codeqwen15_7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        rope_theta=1000000.0,
    )
)
