"""Batch scheduler: group requests by plan signature, cut power-of-two slots.

Requests land in per-group FIFO queues (one group per ``problems.group_key``
— model/size/solver settings + MPO structure).  ``next_batch`` serves the
group whose head request has waited longest (no starvation) and pads the
slot to the next power of two by duplicating the tail request, because jax
keys compiled executables by every leaf shape INCLUDING the batch axis: a
quantized slot-size set {1, 2, 4, ..., max_batch} means the warmup hook can
precompile every size a steady-state batch will ever take, and ragged
arrival counts never retrace.  Filler copies cost compute but not
correctness — their results are dropped on completion.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

from ..dist.plan import bucket_dim


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """One DMRG request: model + Hamiltonian parameters + solver settings.

    ``params`` is a sorted tuple of (name, value) pairs (hashable, so specs
    can key dicts); use ``make`` to build one from kwargs.
    """

    model: str = "heisenberg"
    n_sites: int = 8
    params: Tuple[Tuple[str, float], ...] = ()
    max_bond: int = 16
    sweeps_per_bond: int = 2
    davidson_iters: int = 6
    cutoff: float = 1e-12
    mpo_cutoff: float = 1e-13

    @staticmethod
    def make(model: str = "heisenberg", n_sites: int = 8, **kw) -> "ProblemSpec":
        solver = {
            k: kw.pop(k)
            for k in ("max_bond", "sweeps_per_bond", "davidson_iters",
                      "cutoff", "mpo_cutoff")
            if k in kw
        }
        return ProblemSpec(
            model=model,
            n_sites=n_sites,
            params=tuple(sorted(kw.items())),
            **solver,
        )

    @property
    def bond_schedule(self) -> Tuple[int, ...]:
        """Power-of-two ramp 8, 16, ... up to ``max_bond`` (the bucket set
        the warmup hook precompiles), like the examples drivers use."""
        out: List[int] = []
        m = 8
        while m < self.max_bond:
            out.append(m)
            m *= 2
        out.append(self.max_bond)
        return tuple(out)

    # ------------------------------------------------------- journal (JSON)
    def to_json_dict(self) -> Dict:
        """Plain-JSON form, for the service's crash-recovery journal."""
        d = dataclasses.asdict(self)
        d["params"] = [[k, v] for k, v in self.params]
        return d

    @staticmethod
    def from_json_dict(d: Dict) -> "ProblemSpec":
        """Inverse of ``to_json_dict`` (JSON lists back to hashable tuples)."""
        d = dict(d)
        d["params"] = tuple((k, v) for k, v in d.get("params", ()))
        return ProblemSpec(**d)


@dataclasses.dataclass
class BatchSlot:
    """One schedulable batch: real requests + tail-duplicated filler."""

    key: Tuple                       # the group key
    rids: List[int]                  # request ids, real ones only
    specs: List[ProblemSpec]         # len == slot_size (fillers appended)
    mpos: List                       # per-problem MPOs, len == slot_size
    space: object

    @property
    def n_real(self) -> int:
        return len(self.rids)

    @property
    def slot_size(self) -> int:
        return len(self.specs)

    @property
    def fill_ratio(self) -> float:
        return self.n_real / self.slot_size

    def rid_at(self, b: int) -> int:
        """The request id batch position ``b`` belongs to.

        Filler positions (``b >= n_real``) are tail duplicates, so a
        per-problem failure mask flagging a filler implicates the tail
        request — its real copy shares the filler's values exactly.
        """
        return self.rids[b] if b < self.n_real else self.rids[-1]


def make_slot(key, rids, specs, space, mpos) -> BatchSlot:
    """Build a slot from real requests, padding to the power-of-two size.

    The same tail-duplication rule ``BatchScheduler.next_batch`` uses —
    shared so the service's bisection-retry slots land on the identical
    warmed batch-size buckets as scheduler-cut ones.
    """
    assert len(rids) == len(specs) == len(mpos) and rids
    specs, mpos = list(specs), list(mpos)
    slot = bucket_dim(len(rids))
    while len(specs) < slot:
        specs.append(specs[-1])
        mpos.append(mpos[-1])
    return BatchSlot(
        key=key, rids=list(rids), specs=specs, mpos=mpos, space=space
    )


class BatchScheduler:
    """Per-group FIFO queues with oldest-head-first slot cutting."""

    def __init__(self, max_batch: int = 8):
        assert max_batch >= 1
        self.max_batch = max_batch
        self._queues: "OrderedDict[Tuple, Deque]" = OrderedDict()
        self._seq = itertools.count()

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def add(self, key: Tuple, rid: int, spec: ProblemSpec, space, mpo):
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = deque()
        q.append((next(self._seq), rid, spec, space, mpo))

    def remove(self, rid: int) -> bool:
        """Drop a queued request (cancellation); False if not queued."""
        for key, q in list(self._queues.items()):
            for item in q:
                if item[1] == rid:
                    q.remove(item)
                    if not q:
                        del self._queues[key]
                    return True
        return False

    def oldest_seq(self) -> Optional[int]:
        """Arrival counter of the longest-waiting request (None if empty)."""
        heads = [q[0][0] for q in self._queues.values() if q]
        return min(heads) if heads else None

    def largest_group(self) -> int:
        return max((len(q) for q in self._queues.values()), default=0)

    def next_batch(self) -> Optional[BatchSlot]:
        """Cut a slot from the group whose head request is oldest."""
        best_key, best_seq = None, None
        for key, q in self._queues.items():
            if q and (best_seq is None or q[0][0] < best_seq):
                best_key, best_seq = key, q[0][0]
        if best_key is None:
            return None
        q = self._queues[best_key]
        taken = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        if not q:
            del self._queues[best_key]
        rids = [t[1] for t in taken]
        specs = [t[2] for t in taken]
        space = taken[0][3]
        mpos = [t[4] for t in taken]
        # pad to the power-of-two slot size with tail duplicates so the
        # compiled pipeline only ever sees the warmed batch-size bucket set
        slot = bucket_dim(len(taken))
        while len(specs) < slot:
            specs.append(specs[-1])
            mpos.append(mpos[-1])
        return BatchSlot(key=best_key, rids=rids, specs=specs, mpos=mpos,
                        space=space)
