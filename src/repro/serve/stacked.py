"""Stacked block-sparse tensors: a leading problem axis over shared structure.

The multi-problem solver (DESIGN.md Sec. 3.7) batches B DMRG problems that
share one charge structure — same indices, same block keys, different block
*values* (e.g. a J/h parameter sweep) — by stacking each block along a new
leading axis: a "stacked" ``BlockSparseTensor`` carries ``[B, ...]`` block
arrays while its indices still describe the per-problem structure.

This representation composes with everything PRs 1-5 built, because the
whole plan/execute layer reads only indices / charges / block KEYS (never
values or array ranks):

- plan caches (``dist/plan.py``) accept stacked tensors directly — a batch
  shares its plans (and their compiled cores) with single-problem runs;
- ``jax.vmap`` over the block leaves makes every per-problem traced body
  (matvec, fused env update, bucketed SVD) see ordinary unbatched blocks, so
  the existing engine code runs unchanged inside the batch — ``StackedOps``
  below wraps those bodies in ``jax.jit(jax.vmap(...))`` once per structure;
- structural ops (``flip_flow``, index bookkeeping) never touch data, so
  they work on stacked tensors as-is.

What does NOT compose is anything with per-problem *scalars* (norms, inner
products, scaling): those return/consume ``[B]`` arrays here (``binner``,
``bnorm``, ``bscale``, ``bselect``), and padding must skip the problem axis
(``pad_stacked`` / ``unpad_stacked``).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..dist.engine import ContractionEngine
from ..tensor.blocksparse import BlockKey, BlockSparseTensor
from ..dist.batch import pad_index


# ----------------------------------------------------------- stack / unstack
def stack_tensors(ts: Sequence[BlockSparseTensor]) -> BlockSparseTensor:
    """Stack B same-structure tensors into one stacked tensor ([B, ...] blocks).

    All inputs must agree on indices, charge and block keys — the scheduler
    guarantees this by grouping requests by structure signature; a mismatch
    here means a grouping bug, so it raises instead of broadcasting.
    """
    t0 = ts[0]
    keys = sorted(t0.blocks)
    for t in ts[1:]:
        if t.indices != t0.indices or t.charge != t0.charge:
            raise ValueError("stack_tensors: mismatched index structure")
        if sorted(t.blocks) != keys:
            raise ValueError("stack_tensors: mismatched block keys")
    blocks = {k: jnp.stack([t.blocks[k] for t in ts]) for k in keys}
    return BlockSparseTensor(t0.indices, blocks, t0.charge)


def unstack_tensor(t: BlockSparseTensor, b: int) -> BlockSparseTensor:
    """Extract problem ``b`` from a stacked tensor (unbatched view)."""
    return BlockSparseTensor(
        t.indices, {k: blk[b] for k, blk in t.blocks.items()}, t.charge
    )


def broadcast_tensor(t: BlockSparseTensor, B: int) -> BlockSparseTensor:
    """Replicate an unbatched tensor across B problems (zero-copy view)."""
    blocks = {
        k: jnp.broadcast_to(blk[None], (B,) + tuple(blk.shape))
        for k, blk in t.blocks.items()
    }
    return BlockSparseTensor(t.indices, blocks, t.charge)


def batch_size(t: BlockSparseTensor) -> int:
    for b in t.blocks.values():
        return int(b.shape[0])
    raise ValueError("batch_size of a tensor with no blocks")


# ------------------------------------------------- per-problem scalar algebra
def _bshape(c, nd: int):
    """Reshape a [B] coefficient vector for broadcasting over [B, ...] blocks."""
    return jnp.reshape(jnp.asarray(c), (-1,) + (1,) * nd)


def binner(a: BlockSparseTensor, b: BlockSparseTensor) -> jax.Array:
    """Per-problem <a|b>: a [B] array, summing over shared block keys only
    (the stacked mirror of ``BlockSparseTensor.inner``)."""
    acc = None
    for k, blk in a.blocks.items():
        other = b.blocks.get(k)
        if other is None:
            continue
        axes = tuple(range(1, blk.ndim))
        part = jnp.sum(jnp.conj(blk) * other, axis=axes)
        acc = part if acc is None else acc + part
    return acc


def bnorm_sq(t: BlockSparseTensor) -> jax.Array:
    acc = None
    for blk in t.blocks.values():
        part = jnp.sum(jnp.abs(blk) ** 2, axis=tuple(range(1, blk.ndim)))
        acc = part if acc is None else acc + part
    return jnp.real(acc)


def bnorm(t: BlockSparseTensor) -> jax.Array:
    """Per-problem Frobenius norm, a [B] array."""
    return jnp.sqrt(bnorm_sq(t))


def bscale(t: BlockSparseTensor, c) -> BlockSparseTensor:
    """Scale each problem by its own coefficient (c is a [B] array)."""
    blocks = {}
    for k, blk in t.blocks.items():
        blocks[k] = blk * _bshape(c, blk.ndim - 1).astype(blk.dtype)
    return BlockSparseTensor(t.indices, blocks, t.charge)


def bselect(
    mask, a: BlockSparseTensor, b: BlockSparseTensor
) -> BlockSparseTensor:
    """Per-problem select: problem i takes a's slice where mask[i], else b's.

    Missing blocks on either side count as zeros (like ``__add__``'s union
    semantics), so tensors produced by different pipelines can be merged.
    """
    assert a.indices == b.indices and a.charge == b.charge
    mask = jnp.asarray(mask)
    blocks: Dict[BlockKey, jax.Array] = {}
    for k in set(a.blocks) | set(b.blocks):
        ab = a.blocks.get(k)
        bb = b.blocks.get(k)
        if ab is None:
            ab = jnp.zeros_like(bb)
        if bb is None:
            bb = jnp.zeros_like(ab)
        blocks[k] = jnp.where(_bshape(mask, ab.ndim - 1), ab, bb)
    return BlockSparseTensor(a.indices, blocks, a.charge)


def blincomb(ts: Sequence[BlockSparseTensor], coeffs) -> BlockSparseTensor:
    """sum_j coeffs[:, j] * ts[j], per problem (coeffs is [B, len(ts)])."""
    coeffs = jnp.asarray(coeffs)
    out = bscale(ts[0], coeffs[:, 0])
    for j in range(1, len(ts)):
        out = out + bscale(ts[j], coeffs[:, j])
    return out


# ------------------------------------------------------------------- padding
def pad_stacked(t: BlockSparseTensor) -> BlockSparseTensor:
    """``dist.batch.pad_block_sparse`` for stacked tensors: pad every sector
    dim up to its power-of-two bucket, never touching the problem axis."""
    out = BlockSparseTensor(tuple(pad_index(ix) for ix in t.indices), {}, t.charge)
    blocks: Dict[BlockKey, jax.Array] = {}
    for k, blk in t.blocks.items():
        tgt = out.block_shape(k)
        if tgt == tuple(blk.shape[1:]):
            blocks[k] = blk
        else:
            blocks[k] = jnp.pad(
                blk,
                ((0, 0),) + tuple((0, ts - s) for ts, s in zip(tgt, blk.shape[1:])),
            )
    out.blocks = blocks
    return out


def unpad_stacked(t: BlockSparseTensor, indices) -> BlockSparseTensor:
    """Slice a padded stacked tensor back to the given per-problem structure."""
    out = BlockSparseTensor(indices, {}, t.charge)
    blocks: Dict[BlockKey, jax.Array] = {}
    for k, blk in t.blocks.items():
        tgt = out.block_shape(k)
        if tgt == tuple(blk.shape[1:]):
            blocks[k] = blk
        else:
            blocks[k] = blk[(slice(None),) + tuple(slice(0, s) for s in tgt)]
    out.blocks = blocks
    return out


# -------------------------------------------------------------- StackedOps
class StackedOps:
    """Compiled vmapped pipelines over stacked tensors, with retrace counting.

    One instance per serving process: the jitted callables in ``_fns`` (and
    jax's own trace cache behind them, keyed by block structure AND batch
    size) must persist across batches for steady-state requests to replay
    compiled code.  ``retraces`` counts every (re)trace of any wrapped body —
    the number the serve CLI's ``--check`` asserts stays zero after warmup.

    The per-problem bodies are the existing engine paths verbatim
    (``two_site_matvec``, ``env.update_left/right``, planned contraction);
    ``jax.vmap`` shows them unbatched blocks, so batching cannot change
    per-problem numerics.
    """

    def __init__(self, engine: ContractionEngine | None = None):
        self.engine = engine if engine is not None else ContractionEngine(
            backend="batched"
        )
        self.retraces = 0
        self._fns: Dict = {}

    def _jit_vmap(self, key, body):
        fn = self._fns.get(key)
        if fn is None:
            ops = self

            def traced(*args):
                ops.retraces += 1  # body runs only when jax (re)traces
                return body(*args)

            fn = jax.jit(jax.vmap(traced))
            self._fns[key] = fn
        return fn

    def contract(self, a, b, axes):
        fn = self._jit_vmap(
            ("c", axes), lambda a_, b_: self.engine(a_, b_, axes)
        )
        return fn(a, b)

    def matvec_fn(self, A, Wj, Wj1, B):
        """Batched Davidson matvec closure over fixed stacked operands."""
        mv = self._jit_vmap(
            "mv",
            lambda A_, Wj_, Wj1_, B_, x_: self.engine.two_site_matvec(
                A_, Wj_, Wj1_, B_, x_
            ),
        )
        return lambda x: mv(A, Wj, Wj1, B, x)

    def env_update(self, side, env, T, W):
        """Fused env update per problem (pads + plans inside the trace)."""
        body = (
            self.engine.env.update_left
            if side == "left"
            else self.engine.env.update_right
        )
        fn = self._jit_vmap(("env", side), lambda e_, t_, w_: body(e_, t_, w_))
        return fn(env, T, W)

    def stats(self) -> Dict:
        return {"retraces": self.retraces, "compiled_fns": len(self._fns)}
