"""CLI front end: ``python -m repro.serve`` — batched parameter sweeps.

Expands ``--sweep NAME=a:b:n`` ranges into a cartesian grid of problems,
submits them all through a ``DMRGService`` queue, and prints one row per
problem plus the service stats.  ``--check`` re-solves every problem
individually and asserts the batched energies match to 1e-10 AND that the
warmed pipeline served the whole sweep with zero retraces.

Example (the README quickstart)::

    PYTHONPATH=src python -m repro.serve --model heisenberg --n-sites 8 \
        --max-bond 16 --sweep J=0.8:1.2:4 --batch 4 --check

``--warmup MODEL[,m=BOND][,n=SITES]`` (repeatable, requires
``--plan-store``) switches to warmup-only mode: prime the persistent plan
+ executable store for each named target and exit, so a later worker on
the same store starts its first sweep near steady-state speed (README
"Cold start", DESIGN.md Sec. 3.9)::

    PYTHONPATH=src python -m repro.serve --warmup heisenberg,m=8,n=6 \
        --batch 2 --plan-store /tmp/dmrg_store
"""
from __future__ import annotations

import os

# ``python -m repro.serve`` imports the package __init__ (and through it jax)
# BEFORE this module runs, so an env setdefault here is too late for jax's
# import-time config read — flip the flag through the config API instead.
os.environ.setdefault("JAX_ENABLE_X64", "1")
if os.environ["JAX_ENABLE_X64"] not in ("0", "false", "False"):
    import jax

    jax.config.update("jax_enable_x64", True)

import argparse
import itertools
import json
import sys
import time
from typing import Dict, List, Tuple

import numpy as np


def parse_sweep(arg: str) -> Tuple[str, np.ndarray]:
    """``NAME=a:b:n`` -> (name, linspace(a, b, n)); ``NAME=v`` -> single value."""
    try:
        name, rng = arg.split("=", 1)
        parts = rng.split(":")
        if len(parts) == 1:
            return name, np.array([float(parts[0])])
        lo, hi, n = float(parts[0]), float(parts[1]), int(parts[2])
        if n < 1:
            raise ValueError
        return name, np.linspace(lo, hi, n)
    except ValueError:
        raise SystemExit(
            f"bad --sweep {arg!r}: expected NAME=a:b:n or NAME=value"
        )


def build_grid(sweeps: List[Tuple[str, np.ndarray]]) -> List[Dict[str, float]]:
    """Cartesian product of the swept axes as per-problem parameter dicts."""
    if not sweeps:
        return [{}]
    names = [s[0] for s in sweeps]
    return [
        {n: float(v) for n, v in zip(names, combo)}
        for combo in itertools.product(*(s[1] for s in sweeps))
    ]


def parse_warmup(arg: str, default_m: int, default_n: int):
    """``MODEL[,m=BOND][,n=SITES]`` -> (model, max_bond, n_sites)."""
    parts = arg.split(",")
    model, m, n = parts[0], default_m, default_n
    try:
        for p in parts[1:]:
            k, v = p.split("=", 1)
            if k == "m":
                m = int(v)
            elif k == "n":
                n = int(v)
            else:
                raise ValueError
        if not model:
            raise ValueError
    except ValueError:
        raise SystemExit(
            f"bad --warmup {arg!r}: expected MODEL[,m=BOND][,n=SITES]"
        )
    return model, m, n


def run_warmup(args) -> int:
    """Warmup-only mode: prime the plan store for each --warmup target.

    For every ``MODEL,m=...`` target this runs the service warmup — one full
    solve per power-of-two slot size, covering every bond-schedule structure
    — against the activated ``--plan-store``, then the blocking export
    compile pass.  A fresh worker on the same store afterwards starts its
    first sweep within ~2x of steady state (benchmarks/bench_dist.py
    ``cold_start`` leg) instead of ~20x.
    """
    from repro.dist import store_stats
    from repro.serve import DMRGService, ProblemSpec

    if not args.plan_store:
        print("--warmup requires --plan-store (nowhere to persist) ",
              file=sys.stderr)
        return 2
    svc = DMRGService(max_batch=args.batch, start=False,
                      plan_store=args.plan_store)
    sizes = [s for s in (1, 2, 4, 8, 16, 32, 64) if s <= args.batch]
    try:
        for target in args.warmup:
            model, m, n = parse_warmup(target, args.max_bond, args.n_sites)
            spec = ProblemSpec.make(
                model, n, max_bond=m,
                sweeps_per_bond=args.sweeps_per_bond,
                davidson_iters=args.davidson_iters,
            )
            t0 = time.perf_counter()
            svc.warmup(spec, sizes=sizes)
            print(f"warmed {model} (m={m}, n={n}) x sizes {sizes} in "
                  f"{time.perf_counter() - t0:.1f}s")
        st = store_stats()
        print(f"plan store {st['root']}: {st['saves']} plan saves, "
              f"{st['export_saves']} export saves, "
              f"{st['export_prefetched']} artifacts compiled")
        return 0
    finally:
        svc.shutdown()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Batched DMRG parameter sweeps through the serving queue.",
    )
    ap.add_argument("--model", default="heisenberg",
                    help="registered model name (see repro.serve.MODEL_BUILDERS)")
    ap.add_argument("--n-sites", type=int, default=8)
    ap.add_argument("--max-bond", type=int, default=16)
    ap.add_argument("--sweeps-per-bond", type=int, default=2)
    ap.add_argument("--davidson-iters", type=int, default=6)
    ap.add_argument("--sweep", action="append", default=[], metavar="NAME=a:b:n",
                    help="parameter range (repeat for a cartesian grid)")
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch slot size (padded to powers of two)")
    ap.add_argument("--queue", type=int, default=64,
                    help="admission bound (backpressure threshold)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip precompilation (first batches will retrace)")
    ap.add_argument("--plan-store", metavar="DIR",
                    help="persistent plan + executable store (DESIGN.md 3.9); "
                         "activated for the whole process, primed by warmup")
    ap.add_argument("--warmup", action="append", default=[],
                    metavar="MODEL[,m=BOND][,n=SITES]",
                    help="warmup-only mode: precompile the named model's full "
                         "bond-schedule structure x slot-size set into "
                         "--plan-store, then exit (repeatable)")
    ap.add_argument("--stats-json", metavar="PATH",
                    help="write service + plan-cache stats as JSON ('-' = stdout)")
    ap.add_argument("--checkpoint-dir", metavar="DIR",
                    help="journal undelivered requests here; a restarted "
                         "service with the same dir re-enqueues them")
    ap.add_argument("--check", action="store_true",
                    help="verify vs per-problem solves, zero retraces, and "
                         "a zero recovery ledger (no retries/bisections)")
    args = ap.parse_args(argv)

    if args.warmup:
        return run_warmup(args)

    from repro.core import run_dmrg
    from repro.serve import DEVICE_LOCK, DMRGService, ProblemSpec, group_key
    from repro.serve.problems import build_problem

    grid = build_grid([parse_sweep(s) for s in args.sweep])
    specs = [
        ProblemSpec.make(
            args.model,
            args.n_sites,
            max_bond=args.max_bond,
            sweeps_per_bond=args.sweeps_per_bond,
            davidson_iters=args.davidson_iters,
            **params,
        )
        for params in grid
    ]

    svc = DMRGService(max_batch=args.batch, max_queue=args.queue,
                      checkpoint_dir=args.checkpoint_dir,
                      plan_store=args.plan_store)
    try:
        if not args.no_warmup:
            sizes = [s for s in (1, 2, 4, 8, 16, 32, 64) if s <= args.batch]
            t0 = time.perf_counter()
            # warm one spec per distinct group (structure-changing parameters
            # like h=0 vs h!=0 land in different groups)
            seen = set()
            for spec in specs:
                key = group_key(spec, build_problem(spec)[1])
                if key in seen:
                    continue
                seen.add(key)
                svc.warmup(spec, sizes=sizes)
            print(f"warmup: {len(seen)} group(s) x sizes {sizes} in "
                  f"{time.perf_counter() - t0:.1f}s "
                  f"({svc.ops.retraces} traces)")

        rids = [svc.submit(spec, timeout=60.0) for spec in specs]
        print(f"submitted {len(rids)} problems "
              f"(batch<={args.batch}, queue<={args.queue})")

        results = []
        for rid, spec in zip(rids, specs):
            rec = svc.result(rid, timeout=3600.0)
            results.append(rec)
            label = " ".join(f"{k}={v:g}" for k, v in spec.params)
            print(f"  [{rid:3d}] {label:30s} E = {rec['energy']:+.12f}  "
                  f"(bond {rec['max_bond']}, batch {rec['batch_size']})")

        stats = svc.stats()
        print(
            f"served {stats['completed']} problems in "
            f"{stats['solve_seconds']:.2f}s solve time: "
            f"{stats['problems_per_sec']:.2f} problems/sec, "
            f"fill {stats['batch_fill_ratio']:.2f}, "
            f"retraces {stats['retraces']}"
        )
        if args.stats_json:
            payload = json.dumps(stats, indent=2, default=str)
            if args.stats_json == "-":
                print(payload)
            else:
                with open(args.stats_json, "w") as fh:
                    fh.write(payload + "\n")
                print(f"stats written to {args.stats_json}")

        if args.check:
            worst = 0.0
            for spec, rec in zip(specs, results):
                space, mpo = build_problem(spec)
                with DEVICE_LOCK:  # never compile concurrently with the worker
                    ref = run_dmrg(
                        space,
                        None,
                        spec.n_sites,
                        bond_schedule=spec.bond_schedule,
                        sweeps_per_bond=spec.sweeps_per_bond,
                        davidson_iters=spec.davidson_iters,
                        cutoff=spec.cutoff,
                        mpo=mpo,
                        algo="batched",
                        jit_matvec=True,
                    )
                worst = max(worst, abs(rec["energy"] - ref.energy))
            print(f"check: max |E_batched - E_single| = {worst:.3e}")
            if worst >= 1e-10:
                print("CHECK FAILED: batched energies diverge", file=sys.stderr)
                return 1
            if not args.no_warmup and stats["retraces"] != 0:
                print(
                    f"CHECK FAILED: {stats['retraces']} steady-state retraces",
                    file=sys.stderr,
                )
                return 1
            # with no faults armed, a clean sweep must never touch the
            # recovery machinery
            if not stats["faults"]["armed"]:
                ledger = {k: stats[k] for k in
                          ("retries", "bisections", "worker_restarts")}
                if any(ledger.values()):
                    print(f"CHECK FAILED: nonzero recovery ledger {ledger}",
                          file=sys.stderr)
                    return 1
            print("CHECK OK")
        return 0
    finally:
        svc.shutdown()


if __name__ == "__main__":
    sys.exit(main())
