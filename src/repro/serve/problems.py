"""Model registry + problem building for the serving subsystem.

A *problem* is (model name, n_sites, Hamiltonian parameters) plus solver
settings; ``build_problem`` turns a ``ProblemSpec`` into the (space, MPO)
pair the solver consumes, and ``group_key`` derives the batching identity:
two problems batch together iff they share the model/size/solver settings
AND the MPO block structure (``mpo_structure_signature``), because only then
is the whole compiled sweep identical up to block values.

Parameter values deliberately do NOT enter the group key — that is the whole
point: a J-sweep with 64 values forms one group and rides one compiled
pipeline.  Even degenerate values batch (h=0 keeps the field channel with
zero blocks after compression, structure unchanged); anything that does
change the block structure — a different model, lattice, or sector layout —
is caught by the signature part of the key and lands in a separate group
automatically.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from ..core.models import heisenberg_chain_terms, heisenberg_j1j2_terms
from ..core.mpo import build_mpo, compress_mpo
from ..core.siteops import spin_half_space
from .multicore import mpo_structure_signature

# model name -> builder(n_sites, **params) -> (space, terms).  Parameters not
# passed fall back to the builder defaults, so a spec only names the swept
# ones.
MODEL_BUILDERS: Dict[str, Callable] = {
    # nearest-neighbor Heisenberg chain, params J (coupling) and h (field)
    "heisenberg": lambda n, J=1.0, h=0.0: (
        spin_half_space(),
        heisenberg_chain_terms(n, j=J, h=h),
    ),
    # J1-J2 ladder (Ly=2 strip of the paper's 2D model), params J1 and J2
    "j1j2_ladder": lambda n, J1=1.0, J2=0.5: (
        spin_half_space(),
        heisenberg_j1j2_terms(n // 2, 2, J1, J2, cylinder=False),
    ),
}


def build_problem(spec) -> Tuple:
    """(space, compressed MPO) for a ProblemSpec.

    Pure host work (numpy MPO assembly + compression) — safe to run on the
    submitting thread; the heavy device work happens batched in the solver.
    """
    builder = MODEL_BUILDERS.get(spec.model)
    if builder is None:
        raise ValueError(
            f"unknown model {spec.model!r}; registered: {sorted(MODEL_BUILDERS)}"
        )
    space, terms = builder(spec.n_sites, **dict(spec.params))
    mpo = build_mpo(space, terms, spec.n_sites)
    return space, compress_mpo(mpo, cutoff=spec.mpo_cutoff)


def group_key(spec, mpo) -> Tuple:
    """Batch-group identity: solver settings + MPO block structure."""
    return (
        spec.model,
        spec.n_sites,
        spec.max_bond,
        spec.sweeps_per_bond,
        spec.davidson_iters,
        spec.cutoff,
        mpo_structure_signature(mpo),
    )
