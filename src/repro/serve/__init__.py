"""DMRG-as-a-service: vmapped multi-problem solving + batched serving.

The paper's processing-rate framing (their 99x over ITensor comes from
keeping batched dense GEMMs saturated) extends naturally from one problem to
many: every problem sharing a charge structure is shape-identical after
padding, so a J/h parameter sweep or a disorder scan batches through ONE
compiled pipeline with a leading problem axis.  Throughput (problems/sec),
not single-run latency, is the metric (DESIGN.md Sec. 3.7).

Three layers:

- ``stacked`` / ``multicore``: the multi-problem core — stacked block-sparse
  tensors, batched Davidson / truncated SVD / env updates with per-problem
  host decisions at the existing one-sync points, and ``run_dmrg_multi``;
- ``problems`` / ``scheduler``: model registry, structure-signature grouping
  and power-of-two batch slots with a warmup hook;
- ``service``: the async front end — bounded request queue with
  submit/poll/result, a worker thread draining batch slots, and a structured
  stats endpoint — exposed as ``python -m repro.serve``.
"""
from .multicore import (
    MultiDavidsonInfo,
    MultiDMRGResult,
    MultiProblemEngine,
    davidson_multi,
    mpo_structure_signature,
    run_dmrg_multi,
    svd_split_multi,
)
from .problems import MODEL_BUILDERS, build_problem, group_key
from .scheduler import BatchScheduler, BatchSlot, ProblemSpec, make_slot
from .service import DEVICE_LOCK, DMRGService, ServeQueueFull
from .stacked import StackedOps, broadcast_tensor, stack_tensors, unstack_tensor

__all__ = [
    "BatchScheduler",
    "BatchSlot",
    "DEVICE_LOCK",
    "DMRGService",
    "MODEL_BUILDERS",
    "MultiDavidsonInfo",
    "MultiDMRGResult",
    "MultiProblemEngine",
    "ProblemSpec",
    "ServeQueueFull",
    "StackedOps",
    "broadcast_tensor",
    "build_problem",
    "davidson_multi",
    "group_key",
    "make_slot",
    "mpo_structure_signature",
    "run_dmrg_multi",
    "stack_tensors",
    "svd_split_multi",
    "unstack_tensor",
]
