"""Async serving front end: bounded queue, worker thread, stats endpoint.

``DMRGService`` accepts ``ProblemSpec`` requests (``submit`` -> request id),
solves them in structure-grouped batch slots on a daemon worker thread
through one shared ``StackedOps`` pipeline, and exposes ``poll`` /
``result`` plus a structured ``stats`` endpoint (problems/sec, batch fill
ratio, retraces, plan-cache hit rates, per-stage seconds).

Backpressure: the queue is bounded (``max_queue``); ``submit`` blocks up to
``timeout`` for a slot and then raises ``ServeQueueFull`` — shedding load at
admission instead of growing an unbounded backlog.

Warmup: ``warmup(spec, sizes)`` runs one full solve per power-of-two slot
size OUTSIDE the serving ledger, populating the plan caches and every jitted
callable (all bond-schedule structures x all slot sizes).  After that,
steady-state batches replay compiled code only — ``stats()['retraces']``
counts any (re)trace since the last warmup, and the CLI ``--check`` asserts
it stays zero.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, List, Optional, Sequence

from .. import dist
from .multicore import run_dmrg_multi
from .problems import build_problem, group_key
from .scheduler import BatchScheduler, BatchSlot, ProblemSpec
from .stacked import StackedOps


class ServeQueueFull(Exception):
    """Raised by ``submit`` when the bounded queue stays full past timeout."""


# jaxlib < 0.5 can segfault when two threads hit XLA's backend_compile at
# once.  The worker thread holds this lock for the duration of every batch
# solve (and warmup); in-process clients that run their OWN jax work while a
# service is live (e.g. verification solves) should hold it too.  RLock so a
# client can nest service calls under its own critical section.
DEVICE_LOCK = threading.RLock()


_PENDING, _RUNNING, _DONE, _FAILED = "pending", "running", "done", "failed"


class DMRGService:
    """Batched DMRG serving: submit/poll/result over a worker thread.

    Parameters
    ----------
    max_batch: largest slot the scheduler cuts (slots pad to powers of two).
    max_queue: admission bound — queued-but-unsolved requests beyond this
        block/reject new submits.
    batch_wait_s: how long the worker waits for a partial group to fill
        before cutting an under-full slot (latency/throughput trade).
    ops: shared ``StackedOps``; pass one to share compiled pipelines across
        services, default builds its own.
    start: launch the worker thread (tests set False to drive manually).
    """

    def __init__(
        self,
        max_batch: int = 8,
        max_queue: int = 64,
        batch_wait_s: float = 0.05,
        ops: Optional[StackedOps] = None,
        start: bool = True,
    ):
        self.ops = ops if ops is not None else StackedOps()
        self.scheduler = BatchScheduler(max_batch)
        self.max_queue = max_queue
        self.batch_wait_s = batch_wait_s
        self._cv = threading.Condition()
        self._requests: Dict[int, Dict] = {}
        self._rid = itertools.count()
        self._stop = False
        # serving ledger (warmup excluded)
        self.completed = 0
        self.failed = 0
        self.solve_seconds = 0.0
        self.slots_run = 0
        self.fill_sum = 0.0
        self.stage_seconds = {"davidson": 0.0, "svd": 0.0, "env": 0.0}
        self._retrace_floor = self.ops.retraces
        self._warmed: set = set()
        self._worker: Optional[threading.Thread] = None
        if start:
            # XLA compilation can overflow the default pthread stack when it
            # runs on a secondary thread in a large process (LLVM recursion);
            # give the worker an explicit 64 MiB stack.  Prefer warmup() —
            # which compiles on the calling thread — so the worker only
            # replays compiled code.
            old_stack = threading.stack_size(64 * 1024 * 1024)
            try:
                self._worker = threading.Thread(
                    target=self._worker_loop, name="dmrg-serve", daemon=True
                )
                self._worker.start()
            finally:
                threading.stack_size(old_stack)

    # ----------------------------------------------------------------- client
    def submit(self, spec: ProblemSpec, timeout: Optional[float] = None) -> int:
        """Enqueue a problem; returns a request id.

        Builds the MPO on the calling thread (host-only work; the plan
        caches it touches are lock-protected), derives the batch group, and
        admits the request unless the queue is full past ``timeout``.
        """
        space, mpo = build_problem(spec)
        key = group_key(spec, mpo)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while len(self.scheduler) >= self.max_queue:
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise ServeQueueFull(
                        f"queue full ({self.max_queue} pending) after "
                        f"{timeout}s"
                    )
                if not self._cv.wait(timeout=remaining):
                    raise ServeQueueFull(
                        f"queue full ({self.max_queue} pending) after "
                        f"{timeout}s"
                    )
            rid = next(self._rid)
            self._requests[rid] = {
                "status": _PENDING,
                "spec": spec,
                "submitted": time.monotonic(),
            }
            self.scheduler.add(key, rid, spec, space, mpo)
            self._cv.notify_all()
        return rid

    def poll(self, rid: int) -> Dict:
        """Non-blocking status: {status, and result fields once done}."""
        with self._cv:
            req = self._requests.get(rid)
            if req is None:
                raise KeyError(f"unknown request id {rid}")
            return dict(req)

    def result(self, rid: int, timeout: Optional[float] = None) -> Dict:
        """Block until ``rid`` completes; returns the result record."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                req = self._requests.get(rid)
                if req is None:
                    raise KeyError(f"unknown request id {rid}")
                if req["status"] == _DONE:
                    return dict(req)
                if req["status"] == _FAILED:
                    raise RuntimeError(f"request {rid} failed: {req['error']}")
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"request {rid} not done after {timeout}s")
                self._cv.wait(timeout=remaining)

    # ----------------------------------------------------------------- warmup
    def warmup(self, spec: ProblemSpec, sizes: Sequence[int] = (1, 2, 4, 8)):
        """Precompile the full pipeline for ``spec``'s group at each slot size.

        Runs one complete solve per size with ``size`` copies of ``spec`` —
        covering every bond-schedule structure at every power-of-two batch
        size the scheduler can cut — outside the serving ledger.  After this,
        requests in the group replay compiled code only.
        """
        space, mpo = build_problem(spec)
        sizes = sorted({s for s in sizes if s <= max(
            1, self.scheduler.max_batch)})
        for size in sizes:
            with DEVICE_LOCK:
                run_dmrg_multi(
                    space,
                    spec.n_sites,
                    [mpo] * size,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    cutoff=spec.cutoff,
                    davidson_iters=spec.davidson_iters,
                    ops=self.ops,
                )
        with self._cv:
            self._warmed.add((group_key(spec, mpo), tuple(sizes)))
            self._retrace_floor = self.ops.retraces

    # ----------------------------------------------------------------- worker
    def _worker_loop(self):
        while True:
            with self._cv:
                while not self._stop:
                    oldest = self.scheduler.oldest_seq()
                    if oldest is None:
                        self._cv.wait()
                        continue
                    # cut immediately once a full slot is available; give a
                    # partial group batch_wait_s to fill before running ragged
                    if self.scheduler.largest_group() >= self.scheduler.max_batch:
                        break
                    first = self._requests[
                        min(
                            (r for r, q in self._requests.items()
                             if q["status"] == _PENDING),
                            key=lambda r: self._requests[r]["submitted"],
                        )
                    ]
                    wait = self.batch_wait_s - (
                        time.monotonic() - first["submitted"]
                    )
                    if wait <= 0:
                        break
                    self._cv.wait(timeout=wait)
                if self._stop:
                    return
                slot = self.scheduler.next_batch()
                if slot is None:
                    continue
                for rid in slot.rids:
                    self._requests[rid]["status"] = _RUNNING
                self._cv.notify_all()  # queue drained below max -> admit more
            self._run_slot(slot)

    def _run_slot(self, slot: BatchSlot):
        spec = slot.specs[0]
        t0 = time.perf_counter()
        try:
            with DEVICE_LOCK:
                res = run_dmrg_multi(
                    slot.space,
                    spec.n_sites,
                    slot.mpos,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    cutoff=spec.cutoff,
                    davidson_iters=spec.davidson_iters,
                    ops=self.ops,
                )
        except Exception as exc:  # surface the failure on every request
            with self._cv:
                self.failed += len(slot.rids)
                for rid in slot.rids:
                    self._requests[rid].update(status=_FAILED, error=repr(exc))
                self._cv.notify_all()
            return
        dt = time.perf_counter() - t0
        last = res.sweep_stats[-1]
        with self._cv:
            self.completed += len(slot.rids)
            self.solve_seconds += dt
            self.slots_run += 1
            self.fill_sum += slot.fill_ratio
            for st in res.sweep_stats:
                self.stage_seconds["davidson"] += st.davidson_seconds
                self.stage_seconds["svd"] += st.svd_seconds
                self.stage_seconds["env"] += st.env_seconds
            for b, rid in enumerate(slot.rids):  # fillers beyond rids dropped
                self._requests[rid].update(
                    status=_DONE,
                    energy=float(res.energies[b]),
                    max_bond=int(last.max_bond),
                    trunc_err=float(last.trunc_err[b]),
                    n_sweeps=len(res.sweep_stats),
                    batch_size=slot.slot_size,
                )
            self._cv.notify_all()

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict:
        """Structured serving stats (the ``--stats-json`` payload).

        ``retraces`` counts pipeline (re)traces since the last warmup — the
        steady-state number a warmed group must keep at zero.  Plan-cache
        hit rates come from ``repro.dist.cache_stats`` (the three global
        caches are shared with any in-process single-problem runs).
        """
        with self._cv:
            return {
                "completed": self.completed,
                "failed": self.failed,
                "pending": len(self.scheduler),
                "solve_seconds": self.solve_seconds,
                "problems_per_sec": (
                    self.completed / self.solve_seconds
                    if self.solve_seconds > 0 else 0.0
                ),
                "slots": self.slots_run,
                "batch_fill_ratio": (
                    self.fill_sum / self.slots_run if self.slots_run else 0.0
                ),
                "retraces": self.ops.retraces - self._retrace_floor,
                "retraces_total": self.ops.retraces,
                "warmed_groups": len(self._warmed),
                "stage_seconds": dict(self.stage_seconds),
                "plan_caches": dist.cache_stats(self.ops.engine),
            }

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10)
