"""Async serving front end: bounded queue, worker thread, stats endpoint.

``DMRGService`` accepts ``ProblemSpec`` requests (``submit`` -> request id),
solves them in structure-grouped batch slots on a daemon worker thread
through one shared ``StackedOps`` pipeline, and exposes ``poll`` /
``result`` plus a structured ``stats`` endpoint (problems/sec, batch fill
ratio, retraces, plan-cache hit rates, per-stage seconds).

Backpressure: the queue is bounded (``max_queue``); ``submit`` blocks up to
``timeout`` for a slot and then raises ``ServeQueueFull`` — shedding load at
admission instead of growing an unbounded backlog.

Warmup: ``warmup(spec, sizes)`` runs one full solve per power-of-two slot
size OUTSIDE the serving ledger, populating the plan caches and every jitted
callable (all bond-schedule structures x all slot sizes).  After that,
steady-state batches replay compiled code only — ``stats()['retraces']``
counts any (re)trace since the last warmup, and the CLI ``--check`` asserts
it stays zero.

Robustness (DESIGN.md 3.8): a failed slot never takes healthy requests
down with it.  A ``NumericalHealthError`` with a per-problem mask fails (or
retries) exactly the poisoned requests and re-runs the rest, whose energies
are bit-identical to a clean run (phantom batch slots carry exact zeros, so
batch composition never changes per-problem numerics).  An unmasked failure
bisects the slot and retries each half — O(log B) extra solves isolate one
bad request.  Every failed request carries a retry budget with exponential
backoff.  The worker thread is watchdogged: if it dies, in-flight requests
are re-enqueued and a fresh worker starts (capped restarts).  Delivered
results are EVICTED from the live table into a bounded tombstone map — the
service's memory is O(in-flight + tombstones), not O(lifetime requests).
With ``checkpoint_dir`` set, undelivered request specs are journaled to
disk (atomic JSON) and re-submitted on construction after a process crash.
"""
from __future__ import annotations

import itertools
import json
import os
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .. import dist
from ..dist import faults, persist
from ..dist.faults import FaultInjected, NumericalHealthError
from ..tensor.blocksparse import BlockSparseTensor
from .multicore import run_dmrg_multi
from .problems import build_problem, group_key
from .scheduler import BatchScheduler, BatchSlot, ProblemSpec, make_slot
from .stacked import StackedOps


class ServeQueueFull(Exception):
    """Raised by ``submit`` when the bounded queue stays full past timeout."""


# jaxlib < 0.5 can segfault when two threads hit XLA's backend_compile at
# once.  The worker thread holds this lock for the duration of every batch
# solve (and warmup); in-process clients that run their OWN jax work while a
# service is live (e.g. verification solves) should hold it too.  RLock so a
# client can nest service calls under its own critical section.
DEVICE_LOCK = threading.RLock()


_PENDING, _RUNNING, _DONE, _FAILED, _CANCELLED = (
    "pending", "running", "done", "failed", "cancelled",
)

#: request-record keys never exposed through poll/result/tombstones (bulky
#: tensors held only for re-enqueue and bisection retry)
_INTERNAL_KEYS = ("space", "mpo", "key")

_JOURNAL_NAME = "serve_journal.json"
_JOURNAL_VERSION = 1


def _poison_mpo(mpo):
    """NaN-filled structural copy of one problem's MPO (fault payload)."""
    return [
        BlockSparseTensor(
            t.indices,
            {k: jnp.full_like(b, jnp.nan) for k, b in t.blocks.items()},
            t.charge,
        )
        for t in mpo
    ]


class DMRGService:
    """Batched DMRG serving: submit/poll/result over a worker thread.

    Parameters
    ----------
    max_batch: largest slot the scheduler cuts (slots pad to powers of two).
    max_queue: admission bound — queued-but-unsolved requests beyond this
        block/reject new submits.
    batch_wait_s: how long the worker waits for a partial group to fill
        before cutting an under-full slot (latency/throughput trade).
    ops: shared ``StackedOps``; pass one to share compiled pipelines across
        services, default builds its own.
    start: launch the worker thread (tests set False to drive manually).
    max_retries: per-request retry budget — failed solo re-runs beyond this
        mark the request failed.
    retry_backoff_s: base backoff before a charged retry re-run, doubled
        per retry already spent on the request (0 disables sleeping).
    max_worker_restarts: watchdog cap; a worker death beyond this fails all
        in-flight requests instead of restarting again.
    max_tombstones: delivered/cancelled results kept for late ``poll``.
    checkpoint_dir: when set, undelivered request specs are journaled there
        (``serve_journal.json``, atomic rewrite) and re-submitted on the
        next construction with the same directory — completed-but-
        undelivered work is recomputed, which determinism makes exact.
    plan_store: a ``repro.dist.PlanStore`` or path; activated process-wide
        for the life of the service in long-lived-worker mode
        (``prefetch="compile"``): plans, exported cores and compiled
        executables load from the store in the background, so a service on
        a warmed store reaches steady-state throughput on its first slot
        (~2x a steady sweep instead of ~20x; DESIGN.md Sec. 3.9).
        ``warmup`` writes back what it compiles, including the blocking
        export-compile pass that completes the store's cold-start contract.
    """

    def __init__(
        self,
        max_batch: int = 8,
        max_queue: int = 64,
        batch_wait_s: float = 0.05,
        ops: Optional[StackedOps] = None,
        start: bool = True,
        max_retries: int = 2,
        retry_backoff_s: float = 0.0,
        max_worker_restarts: int = 5,
        max_tombstones: int = 256,
        checkpoint_dir: Optional[str] = None,
        plan_store=None,
    ):
        self.plan_store = None
        if plan_store is not None:
            self.plan_store = persist.activate_store(
                plan_store, prefetch="compile"
            )
        self.ops = ops if ops is not None else StackedOps()
        self.scheduler = BatchScheduler(max_batch)
        self.max_queue = max_queue
        self.batch_wait_s = batch_wait_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.max_worker_restarts = max_worker_restarts
        self.max_tombstones = max_tombstones
        self.checkpoint_dir = checkpoint_dir
        self._cv = threading.Condition()
        self._requests: Dict[int, Dict] = {}
        self._delivered: "OrderedDict[int, Dict]" = OrderedDict()
        self._rid = itertools.count()
        self._stop = False
        # serving ledger (warmup excluded)
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.retries = 0
        self.bisections = 0
        self.worker_restarts = 0
        self.solve_seconds = 0.0
        self.slots_run = 0
        self.fill_sum = 0.0
        self.stage_seconds = {"davidson": 0.0, "svd": 0.0, "env": 0.0}
        # Davidson health aggregates over served slots (real problems only)
        self.davidson_health = {
            "solves": 0, "converged": 0, "iterations": 0, "restarts": 0,
        }
        self._retrace_floor = self.ops.retraces
        self._warmed: set = set()
        self._worker: Optional[threading.Thread] = None
        if checkpoint_dir is not None:
            os.makedirs(checkpoint_dir, exist_ok=True)
            self._recover_journal()
        if start:
            self._start_worker()

    def _start_worker(self):
        # XLA compilation can overflow the default pthread stack when it
        # runs on a secondary thread in a large process (LLVM recursion);
        # give the worker an explicit 64 MiB stack.  Prefer warmup() —
        # which compiles on the calling thread — so the worker only
        # replays compiled code.
        old_stack = threading.stack_size(64 * 1024 * 1024)
        try:
            self._worker = threading.Thread(
                target=self._worker_loop, name="dmrg-serve", daemon=True
            )
            self._worker.start()
        finally:
            threading.stack_size(old_stack)

    # ----------------------------------------------------------------- client
    def submit(self, spec: ProblemSpec, timeout: Optional[float] = None) -> int:
        """Enqueue a problem; returns a request id.

        Builds the MPO on the calling thread (host-only work; the plan
        caches it touches are lock-protected), derives the batch group, and
        admits the request unless the queue is full past ``timeout``.
        """
        space, mpo = build_problem(spec)
        key = group_key(spec, mpo)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while len(self.scheduler) >= self.max_queue:
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise ServeQueueFull(
                        f"queue full ({self.max_queue} pending) after "
                        f"{timeout}s"
                    )
                if not self._cv.wait(timeout=remaining):
                    raise ServeQueueFull(
                        f"queue full ({self.max_queue} pending) after "
                        f"{timeout}s"
                    )
            rid = next(self._rid)
            self._requests[rid] = {
                "status": _PENDING,
                "spec": spec,
                "submitted": time.monotonic(),
                "retries": 0,
                # held for re-enqueue after a worker death and for
                # bisection-retry slot rebuilds; never exposed to clients
                "space": space,
                "mpo": mpo,
                "key": key,
            }
            self.scheduler.add(key, rid, spec, space, mpo)
            self._journal_sync()
            self._cv.notify_all()
        return rid

    def _public(self, req: Dict) -> Dict:
        return {k: v for k, v in req.items() if k not in _INTERNAL_KEYS}

    def poll(self, rid: int) -> Dict:
        """Non-blocking status: {status, and result fields once done}.

        Delivered (and cancelled) requests answer from the bounded
        tombstone map; only ids evicted past ``max_tombstones`` raise.
        """
        with self._cv:
            req = self._requests.get(rid)
            if req is not None:
                return self._public(req)
            tomb = self._delivered.get(rid)
            if tomb is not None:
                return dict(tomb)
            raise KeyError(f"unknown request id {rid}")

    def result(self, rid: int, timeout: Optional[float] = None) -> Dict:
        """Block until ``rid`` completes; returns the result record.

        Delivery EVICTS the request from the live table into the tombstone
        map (fixing the delivered-result leak: a long-lived service no
        longer accumulates every result it ever produced).  A repeated
        ``result``/``poll`` for a recently delivered id still answers.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                req = self._requests.get(rid)
                if req is None:
                    tomb = self._delivered.get(rid)
                    if tomb is None:
                        raise KeyError(f"unknown request id {rid}")
                    if tomb["status"] == _DONE:
                        return dict(tomb)
                    raise RuntimeError(
                        f"request {rid} {tomb['status']}: "
                        f"{tomb.get('error', '')}"
                    )
                if req["status"] == _DONE:
                    rec = self._public(req)
                    self._evict(rid)
                    return rec
                if req["status"] == _FAILED:
                    err = req["error"]
                    self._evict(rid)
                    raise RuntimeError(f"request {rid} failed: {err}")
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"request {rid} not done after {timeout}s")
                self._cv.wait(timeout=remaining)

    def cancel(self, rid: int) -> bool:
        """Cancel a still-pending request; False once running or finished.

        A running slot cannot be interrupted mid-solve (the compiled batch
        is already on the device), so cancellation is admission-queue only —
        the honest contract, not a best-effort lie.
        """
        with self._cv:
            req = self._requests.get(rid)
            if req is None or req["status"] != _PENDING:
                return False
            self.scheduler.remove(rid)
            req["status"] = _CANCELLED
            self.cancelled += 1
            self._evict(rid)
            self._cv.notify_all()
            return True

    def _evict(self, rid: int) -> None:
        """Move a finished request to the bounded tombstone map (cv held)."""
        req = self._requests.pop(rid, None)
        if req is None:
            return
        self._delivered[rid] = self._public(req)
        while len(self._delivered) > self.max_tombstones:
            self._delivered.popitem(last=False)
        self._journal_sync()

    # ---------------------------------------------------------------- journal
    def _journal_path(self) -> str:
        return os.path.join(self.checkpoint_dir, _JOURNAL_NAME)

    def _journal_sync(self) -> None:
        """Atomically rewrite the undelivered-request journal (cv held).

        Journaled: every live request that has not been delivered —
        pending, running, and done-but-unfetched (results are not
        persisted, so recovery recomputes them; determinism makes the
        recomputation exact).
        """
        if self.checkpoint_dir is None:
            return
        entries = [
            [rid, req["spec"].to_json_dict(), req["status"]]
            for rid, req in sorted(self._requests.items())
            if req["status"] in (_PENDING, _RUNNING, _DONE)
        ]
        payload = {"version": _JOURNAL_VERSION, "requests": entries}
        fd, tmp = tempfile.mkstemp(
            dir=self.checkpoint_dir, prefix=".journal_", suffix=".json"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self._journal_path())
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _recover_journal(self) -> None:
        """Re-submit journaled requests from a previous process (same rids)."""
        try:
            with open(self._journal_path()) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            return
        if payload.get("version") != _JOURNAL_VERSION:
            return
        max_rid = -1
        for rid, spec_dict, _status in payload.get("requests", []):
            rid = int(rid)
            spec = ProblemSpec.from_json_dict(spec_dict)
            space, mpo = build_problem(spec)
            key = group_key(spec, mpo)
            self._requests[rid] = {
                "status": _PENDING,
                "spec": spec,
                "submitted": time.monotonic(),
                "retries": 0,
                "recovered": True,
                "space": space,
                "mpo": mpo,
                "key": key,
            }
            self.scheduler.add(key, rid, spec, space, mpo)
            max_rid = max(max_rid, rid)
        self._rid = itertools.count(max_rid + 1)

    # ----------------------------------------------------------------- warmup
    def warmup(self, spec: ProblemSpec, sizes: Sequence[int] = (1, 2, 4, 8)):
        """Precompile the full pipeline for ``spec``'s group at each slot size.

        Runs one complete solve per size with ``size`` copies of ``spec`` —
        covering every bond-schedule structure at every power-of-two batch
        size the scheduler can cut — outside the serving ledger.  After this,
        requests in the group replay compiled code only.

        With a plan store attached, the solves also *prime the store* (plans,
        exports, compiled executables), and a final blocking
        ``prefetch_exports(compile=True)`` pass compiles every exported
        core's wrapped module into the persistent compilation cache — the
        second half of the cold-start contract: a FRESH worker process on
        this store then replays everything and lands its first sweep within
        ~2x of steady state.
        """
        space, mpo = build_problem(spec)
        sizes = sorted({s for s in sizes if s <= max(
            1, self.scheduler.max_batch)})
        for size in sizes:
            with DEVICE_LOCK:
                run_dmrg_multi(
                    space,
                    spec.n_sites,
                    [mpo] * size,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    cutoff=spec.cutoff,
                    davidson_iters=spec.davidson_iters,
                    ops=self.ops,
                )
        store = persist.active_store()
        if store is not None:
            with DEVICE_LOCK:
                store.prefetch_exports(compile=True, block=True)
        with self._cv:
            self._warmed.add((group_key(spec, mpo), tuple(sizes)))
            self._retrace_floor = self.ops.retraces

    # ----------------------------------------------------------------- worker
    def _worker_loop(self):
        try:
            self._worker_body()
        except BaseException as exc:
            self._on_worker_death(exc)

    def _worker_body(self):
        while True:
            with self._cv:
                while not self._stop:
                    oldest = self.scheduler.oldest_seq()
                    if oldest is None:
                        self._cv.wait()
                        continue
                    # cut immediately once a full slot is available; give a
                    # partial group batch_wait_s to fill before running ragged
                    if self.scheduler.largest_group() >= self.scheduler.max_batch:
                        break
                    first = self._requests[
                        min(
                            (r for r, q in self._requests.items()
                             if q["status"] == _PENDING),
                            key=lambda r: self._requests[r]["submitted"],
                        )
                    ]
                    wait = self.batch_wait_s - (
                        time.monotonic() - first["submitted"]
                    )
                    if wait <= 0:
                        break
                    self._cv.wait(timeout=wait)
                if self._stop:
                    return
                slot = self.scheduler.next_batch()
                if slot is None:
                    continue
                for rid in slot.rids:
                    self._requests[rid]["status"] = _RUNNING
                self._journal_sync()
                self._cv.notify_all()  # queue drained below max -> admit more
            # fault point: kill the worker thread BETWEEN marking requests
            # running and solving — outside the per-slot recovery, so only
            # the watchdog (re-enqueue + restart) can save the in-flight work
            if faults.fire("serve.worker_crash") is not None:
                raise FaultInjected("serve.worker_crash")
            self._run_slot(slot)

    def _on_worker_death(self, exc: BaseException):
        """Watchdog: re-enqueue in-flight work, restart the worker (capped)."""
        restart = False
        with self._cv:
            if self._stop:
                return
            self.worker_restarts += 1
            restart = self.worker_restarts <= self.max_worker_restarts
            for rid, req in list(self._requests.items()):
                if req["status"] != _RUNNING:
                    continue
                if restart:
                    # never delivered anything for these; solving them again
                    # is exact (determinism), so re-enqueue is safe
                    req["status"] = _PENDING
                    req["submitted"] = time.monotonic()
                    self.scheduler.add(
                        req["key"], rid, req["spec"], req["space"], req["mpo"]
                    )
                else:
                    self.failed += 1
                    req.update(
                        status=_FAILED,
                        error=(
                            f"worker died {self.worker_restarts} times "
                            f"(cap {self.max_worker_restarts}): {exc!r}"
                        ),
                    )
            self._journal_sync()
            self._cv.notify_all()
        if restart:
            self._start_worker()

    # ------------------------------------------------------------- slot solve
    def _run_slot(self, slot: BatchSlot):
        # fault point: artificial latency (value = seconds), e.g. a slow node
        f = faults.fire("serve.slot_latency")
        if f is not None and f.value > 0:
            time.sleep(float(f.value))
        mpos = slot.mpos
        # fault point: NaN-poison the MPO of ONE request (problem = rid) in
        # a local copy — retries rebuild from the pristine stored MPO, so a
        # count=1 fault is transient and a count=inf fault follows the rid
        # through bisection, exactly like a corrupted upstream input would
        fp = faults.fire("serve.poison_request")
        if fp is not None:
            target = int(fp.problem)
            mpos = [
                _poison_mpo(m) if slot.rid_at(b) == target else m
                for b, m in enumerate(mpos)
            ]
        spec = slot.specs[0]
        t0 = time.perf_counter()
        try:
            with DEVICE_LOCK:
                res = run_dmrg_multi(
                    slot.space,
                    spec.n_sites,
                    mpos,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    cutoff=spec.cutoff,
                    davidson_iters=spec.davidson_iters,
                    ops=self.ops,
                )
        except NumericalHealthError as exc:
            if exc.problems is not None:
                self._retry_masked(slot, np.asarray(exc.problems, bool), exc)
            else:
                self._retry_split(slot, exc)
            return
        except Exception as exc:
            self._retry_split(slot, exc)
            return
        dt = time.perf_counter() - t0
        last = res.sweep_stats[-1]
        with self._cv:
            self.solve_seconds += dt
            self.slots_run += 1
            self.fill_sum += slot.fill_ratio
            for st in res.sweep_stats:
                self.stage_seconds["davidson"] += st.davidson_seconds
                self.stage_seconds["svd"] += st.svd_seconds
                self.stage_seconds["env"] += st.env_seconds
                self.davidson_health["solves"] += st.davidson_solves
                self.davidson_health["iterations"] += st.davidson_iterations
                self.davidson_health["restarts"] += st.davidson_restarts
                if st.davidson_converged is not None:
                    self.davidson_health["converged"] += int(
                        st.davidson_converged[: slot.n_real].sum()
                    )
            for b, rid in enumerate(slot.rids):  # fillers beyond rids dropped
                req = self._requests.get(rid)
                if req is None or req["status"] != _RUNNING:
                    continue  # raced with cancellation
                self.completed += 1
                req.update(
                    status=_DONE,
                    energy=float(res.energies[b]),
                    max_bond=int(last.max_bond),
                    trunc_err=float(last.trunc_err[b]),
                    n_sweeps=len(res.sweep_stats),
                    batch_size=slot.slot_size,
                )
            self._journal_sync()
            self._cv.notify_all()

    # --------------------------------------------------------- slot recovery
    def _retry_masked(
        self, slot: BatchSlot, mask: np.ndarray, exc: NumericalHealthError
    ):
        """Per-problem isolation: fail/retry flagged requests, re-run the rest.

        The [B] mask pinpoints the poisoned batch positions (filler
        positions implicate the tail request they duplicate).  Healthy
        requests are re-run together WITHOUT charging their retry budget —
        they were victims — and phantom-slot exactness guarantees their
        re-run energies match a clean run bit-for-bit.
        """
        bad_rids = sorted({slot.rid_at(b) for b in np.flatnonzero(mask)})
        good: List[int] = [r for r in slot.rids if r not in bad_rids]
        by_rid = {rid: (slot.specs[b], slot.mpos[b])
                  for b, rid in enumerate(slot.rids)}
        for rid in bad_rids:
            self._charge_retry(rid, slot.key, by_rid[rid], slot.space, exc)
        if good:
            self._run_slot(make_slot(
                slot.key,
                good,
                [by_rid[r][0] for r in good],
                slot.space,
                [by_rid[r][1] for r in good],
            ))

    def _retry_split(self, slot: BatchSlot, exc: Exception):
        """Unmasked failure: bisect the slot, retry halves; singles charge
        the retry budget.  O(log B) extra solves isolate one bad request."""
        if slot.n_real > 1:
            with self._cv:
                self.bisections += 1
            mid = slot.n_real // 2
            for lo, hi in ((0, mid), (mid, slot.n_real)):
                self._run_slot(make_slot(
                    slot.key,
                    slot.rids[lo:hi],
                    slot.specs[lo:hi],
                    slot.space,
                    slot.mpos[lo:hi],
                ))
            return
        rid = slot.rids[0]
        self._charge_retry(
            rid, slot.key, (slot.specs[0], slot.mpos[0]), slot.space, exc
        )

    def _charge_retry(self, rid, key, spec_mpo, space, exc):
        """Spend one unit of ``rid``'s retry budget on a solo re-run."""
        spec, mpo = spec_mpo
        with self._cv:
            req = self._requests.get(rid)
            if req is None or req["status"] != _RUNNING:
                return  # cancelled or already resolved elsewhere
            req["retries"] += 1
            self.retries += 1
            if req["retries"] > self.max_retries:
                self.failed += 1
                req.update(status=_FAILED, error=repr(exc))
                self._journal_sync()
                self._cv.notify_all()
                return
            backoff = self.retry_backoff_s * (2 ** (req["retries"] - 1))
        if backoff > 0:
            time.sleep(backoff)
        self._run_slot(make_slot(key, [rid], [spec], space, [mpo]))

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict:
        """Structured serving stats (the ``--stats-json`` payload).

        ``retraces`` counts pipeline (re)traces since the last warmup — the
        steady-state number a warmed group must keep at zero.  Plan-cache
        hit rates come from ``repro.dist.cache_stats`` (the three global
        caches are shared with any in-process single-problem runs).
        ``retries``/``bisections``/``worker_restarts`` are the recovery
        ledger — all zero on a healthy run (the clean bench leg asserts
        it); ``davidson`` aggregates per-solve health (solves, per-problem
        residual convergences, iterations, breakdown restarts) and
        ``faults`` reports what injection points are armed/fired.
        """
        with self._cv:
            return {
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "pending": len(self.scheduler),
                "delivered_tombstones": len(self._delivered),
                "solve_seconds": self.solve_seconds,
                "problems_per_sec": (
                    self.completed / self.solve_seconds
                    if self.solve_seconds > 0 else 0.0
                ),
                "slots": self.slots_run,
                "batch_fill_ratio": (
                    self.fill_sum / self.slots_run if self.slots_run else 0.0
                ),
                "retries": self.retries,
                "bisections": self.bisections,
                "worker_restarts": self.worker_restarts,
                "retraces": self.ops.retraces - self._retrace_floor,
                "retraces_total": self.ops.retraces,
                "warmed_groups": len(self._warmed),
                "stage_seconds": dict(self.stage_seconds),
                "davidson": dict(self.davidson_health),
                "faults": faults.registry.stats(),
                "plan_caches": dist.cache_stats(self.ops.engine),
            }

    def shutdown(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10)
