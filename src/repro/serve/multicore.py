"""Multi-problem DMRG core: B parameter-sweep problems through one pipeline.

``davidson_multi`` / ``svd_split_multi`` / ``MultiProblemEngine`` mirror
``core/davidson.py`` / ``dist/decomp.py`` / ``core/sweep.py`` over stacked
tensors (``serve/stacked.py``): every device-side body is the existing
single-problem code wrapped in ``jax.vmap`` — per-problem numerics cannot
diverge from a single run by construction — and every host-side decision
(Davidson convergence, global truncation) is made independently per problem
at the SAME one-sync points the single-problem engines already have, so a
batch of B problems costs the same number of host round-trips as one.

Per-problem truncation inside one shared block structure works by masking:
each split keeps ``max_b m_q[b]`` bond states per sector (the batch bond is
the union), and zeroes each problem's U columns, V rows AND singular values
beyond its own retained count.  Both sides must be masked — a nonzero
orthonormal U column with a zeroed V row would still leak into the
environments.  The retained values within a sector are always a prefix
(singular values descend, ties break by position), so prefix masks are
exact.  Phantom bond slots then carry exact zeros through envs, matvecs and
later splits: each problem evolves exactly as if it ran alone at its own
bond dimension (tests/test_serve.py asserts <1e-10 on energies and svals).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.davidson import GRAM_NOISE_FLOOR, GS_BREAKDOWN_TOL
from ..core.env import left_edge, right_edge
from ..core.mps import neel_states, product_state_mps
from ..dist import faults
from ..dist.decomp import _cache_exec, host_truncate, svd_core_body
from ..dist.faults import FaultInjected, NumericalHealthError
from ..dist.plan import global_decomp_cache
from ..tensor.blocksparse import BlockSparseTensor, flip_flow
from ..tensor.qn import IN, Index, OUT, qzero
from .stacked import (
    StackedOps,
    batch_size,
    binner,
    blincomb,
    bnorm,
    broadcast_tensor,
    bscale,
    bselect,
    pad_stacked,
    stack_tensors,
    unpad_stacked,
)


def mpo_structure_signature(mpo: Sequence[BlockSparseTensor]) -> Tuple:
    """Structural signature of an MPO: per site (indices, charge, block keys).

    Two problems batch together iff their MPOs share this signature — then
    every plan, compiled core and padded structure of the sweep is identical
    and the batch axis is purely a value axis.
    """
    return tuple(
        (t.indices, t.charge, tuple(sorted(t.blocks))) for t in mpo
    )


# ------------------------------------------------------------------ Davidson
@dataclasses.dataclass
class MultiDavidsonInfo:
    """Health record of one batched Davidson solve (``DavidsonInfo`` mirror).

    ``converged`` is a per-problem [B] bool array — as in the single solver,
    False on a budget-limited production solve means "unknown", not
    "diverged".  ``restarts`` counts Gram-Schmidt breakdown events (batch
    restarts are issued for all broken-down problems at once).
    """

    converged: np.ndarray
    iterations: int = 0
    restarts: int = 0


def _new_columns_multi(V, AV, i) -> np.ndarray:
    """M[:, j, i] and W[:, j, i] for j <= i, one device round-trip: [2(i+1), B]."""
    vals = [binner(V[j], AV[i]) for j in range(i + 1)]
    vals += [binner(AV[j], AV[i]) for j in range(i + 1)]
    return np.real(np.asarray(jax.device_get(jnp.stack(vals))))


def _check_cols_multi(cols: np.ndarray, i: int) -> None:
    """Per-problem health guard on the one existing sync per iteration.

    ``cols`` is [2(i+1), B]; vmap keeps problems independent, so a column
    that is non-finite pinpoints exactly the poisoned problems — the mask
    lets the serving layer fail those requests and retry the rest.
    """
    bad = ~np.isfinite(cols).all(axis=0)
    if bad.any():
        raise NumericalHealthError(
            f"non-finite Rayleigh-Ritz entries at iteration {i} for "
            f"problems {np.flatnonzero(bad).tolist()}",
            stage="davidson",
            problems=bad,
        )


def davidson_multi(
    matvec: Callable[[BlockSparseTensor], BlockSparseTensor],
    x0: BlockSparseTensor,
    n_iter: int = 2,
    tol: float = 1e-10,
    seed: int = 0,
) -> Tuple[np.ndarray, BlockSparseTensor, MultiDavidsonInfo]:
    """Batched ``core.davidson.davidson``: per-problem eigenpairs, shared syncs.

    The subspace vectors are stacked, so each problem spans its OWN Krylov
    space; only the sync points are shared.  Host-side control flow mirrors
    the single solver exactly per problem — same Gram-identity residual with
    the same noise floor, same exact-norm fallback, same Gram-Schmidt
    breakdown threshold and same seeded restart — except that a converged
    problem keeps riding along (its recorded Ritz data frozen, its residual
    column near zero) until the whole batch finishes.  Returns
    ``(eigenvalues [B], stacked eigenvector approximation, health info)``.

    Health guard: the Rayleigh-Ritz column read is checked per problem
    (``_check_cols_multi``) at zero extra sync cost; a NaN-poisoned problem
    raises ``NumericalHealthError`` carrying the [B] mask of exactly the
    poisoned batch positions.
    """
    B = batch_size(x0)
    force_no_converge = faults.fire("davidson.no_converge") is not None
    x = bscale(x0, 1.0 / bnorm(x0))
    V = [x]
    AV = [matvec(x)]
    if n_iter <= 0:
        lam = np.real(np.asarray(jax.device_get(binner(V[0], AV[0]))))
        bad = ~np.isfinite(lam)
        if bad.any():
            raise NumericalHealthError(
                "non-finite Rayleigh quotient",
                stage="davidson",
                problems=bad,
            )
        return lam, x, MultiDavidsonInfo(converged=np.zeros(B, dtype=bool))

    dim = n_iter + 1
    M = np.zeros((B, dim, dim))  # <v_j | A v_i> per problem
    W = np.zeros((B, dim, dim))  # <A v_j | A v_i> per problem
    keep_s = np.zeros((B, dim))
    keep_s[:, 0] = 1.0
    keep_lam = np.zeros(B)
    done = np.zeros(B, dtype=bool)
    info = MultiDavidsonInfo(converged=np.zeros(B, dtype=bool))

    for i in range(n_iter):
        cols = _new_columns_multi(V, AV, i)
        _check_cols_multi(cols, i)
        info.iterations = i + 1
        M[:, : i + 1, i] = M[:, i, : i + 1] = cols[: i + 1].T
        W[:, : i + 1, i] = W[:, i, : i + 1] = cols[i + 1 :].T
        evals, evecs = np.linalg.eigh(M[:, : i + 1, : i + 1])
        lam, s = evals[:, 0], evecs[:, :, 0]
        act = ~done
        # freeze this iteration's Ritz data for still-active problems; a
        # problem that converges below keeps exactly the state it broke on
        keep_lam[act] = lam[act]
        keep_s[act, : i + 1] = s[act]
        keep_s[act, i + 1 :] = 0.0
        if i == n_iter - 1:
            break

        # residual q = A x - lam x (device-side), norm from the Gram identity
        # above the per-problem cancellation noise floor, measured exactly
        # otherwise (converged regime only) — one batch sync either way
        q = blincomb(AV[: i + 1], s) - bscale(blincomb(V[: i + 1], s), lam)
        qn2_gram = np.einsum("bi,bij,bj->b", s, W[:, : i + 1, : i + 1], s) - lam * lam
        noise_floor = GRAM_NOISE_FLOOR * np.maximum(1.0, lam * lam)
        qn = np.sqrt(np.where(qn2_gram > 0.0, qn2_gram, 0.0))
        need_exact = act & ~(qn2_gram > noise_floor)
        if need_exact.any():
            qn_exact = np.asarray(jax.device_get(bnorm(q)))
            qn = np.where(need_exact, qn_exact, qn)
        if not force_no_converge:
            done = done | (act & (qn < tol))
        if done.all():
            break

        # modified Gram-Schmidt vs all v_j, per-problem coefficients
        for j in range(i + 1):
            q = q - bscale(V[j], binner(V[j], q))
        qn2 = np.asarray(jax.device_get(bnorm(q)))
        breakdown = (~done) & (qn2 < GS_BREAKDOWN_TOL * np.maximum(qn, 1.0))
        if breakdown.any():
            info.restarts += 1
            # restart with A·(random), confined to range(A) like the single
            # solver; the same PRNG key on the same structure gives the same
            # restart vector a padded single run would draw
            r = matvec(
                broadcast_tensor(
                    BlockSparseTensor.random(
                        x0.indices, x0.charge, jax.random.PRNGKey(seed + i),
                        dtype=x0.dtype,
                    ),
                    B,
                )
            )
            for j in range(i + 1):
                r = r - bscale(V[j], binner(V[j], r))
            rn2 = np.asarray(jax.device_get(bnorm(r)))
            q = bselect(breakdown, r, q)
            qn2 = np.where(breakdown, rn2, qn2)
        # converged problems still need a FINITE column (their residual is
        # ~0); leave it unscaled instead of dividing by its vanishing norm
        denom = np.where(done | (qn2 == 0.0), 1.0, qn2)
        q = bscale(q, 1.0 / denom)
        V.append(q)
        AV.append(matvec(q))

    x = blincomb(V, keep_s[:, : len(V)])
    info.converged = done.copy()
    return keep_lam.copy(), bscale(x, 1.0 / bnorm(x)), info


# ----------------------------------------------------------------- SVD split
def _slice_core_body_multi(plan, m_q: Tuple[int, ...]):
    """Per-problem variant of ``dist.decomp.slice_core_body``: additionally
    multiplies each sector's U columns, V rows and singular values by a
    per-problem prefix mask, zeroing the bond slots beyond that problem's own
    retained count (see module docstring)."""

    def body(bucket_out, masks):
        u_out, v_out, s_out = [], [], []
        mi = 0
        for si, sec in enumerate(plan.sectors):
            m = m_q[si]
            if m == 0:
                continue
            mask = masks[mi]
            mi += 1
            U, s, Vh = bucket_out[sec.bucket]
            Uq, Vq = U[sec.slot], Vh[sec.slot]
            s_out.append(s[sec.slot, :m] * mask)
            for rk, rd, ro in zip(sec.row_keys, sec.rdims, sec.roffs):
                shp = tuple(
                    ix.sector_dim(sk) for ix, sk in zip(plan.row_ix, rk)
                ) + (m,)
                u_out.append((Uq[ro : ro + rd, :m] * mask[None, :]).reshape(shp))
            for ck, cd, co in zip(sec.col_keys, sec.cdims, sec.coffs):
                shp = (m,) + tuple(
                    ix.sector_dim(sk) for ix, sk in zip(plan.col_ix, ck)
                )
                v_out.append((Vq[:m, co : co + cd] * mask[:, None]).reshape(shp))
        return tuple(u_out), tuple(v_out), tuple(s_out)

    return body


def svd_split_multi(
    theta: BlockSparseTensor,
    n_row_modes: int,
    max_bond: int,
    cutoff: float = 1e-12,
    absorb: str = "right",
    ops: Optional[StackedOps] = None,
):
    """Batched planned truncated SVD over a stacked theta.

    One vmapped ``svd_core_body`` call (plan shared with single-problem runs
    through the global DecompPlanCache), ONE host sync of all B problems'
    singular values, B independent ``host_truncate`` decisions — the exact
    single-problem logic — and one vmapped masked slice core.  Returns
    ``(U, V, svals_by_sector [B, m], trunc_err [B])``; problem b's retained
    values are the first ``m_q[b]`` entries of each sector, zeros beyond.
    """
    # fault point: forced failure of the stacked SVD core, standing in for
    # LAPACK non-convergence.  No per-problem mask — the whole core call
    # fails — so the serving layer recovers by slot bisection, not masking.
    if faults.fire("decomp.svd_fail") is not None:
        raise FaultInjected(
            "decomp.svd_fail", "stacked batched SVD did not converge"
        )
    plan = global_decomp_cache.get(theta, n_row_modes)
    methods = ("svd",) * plan.num_buckets
    absorb_key = absorb if absorb in ("left", "right") else "none"
    key = ("multi", absorb_key)
    core = plan._exec.get(key)
    if core is None:
        body = svd_core_body(plan, absorb_key, methods, 0)
        core = _wrap_multi(body, ops)
        _cache_exec(plan, key, core)
    bucket_out, s_cat = core(tuple(theta.blocks[k] for k in plan.block_order))

    # ---- the one host sync: all B problems' masked singular values
    s_host = np.asarray(jax.device_get(s_cat))  # [B, total]
    # per-problem health guard on the existing sync (vmap keeps problems
    # independent, so a non-finite row pinpoints the poisoned ones)
    bad = ~np.isfinite(s_host).all(axis=1)
    if bad.any():
        raise NumericalHealthError(
            f"non-finite singular values for problems "
            f"{np.flatnonzero(bad).tolist()}",
            stage="svd",
            problems=bad,
        )
    B = s_host.shape[0]
    k_out = [int(out[1].shape[-1]) for out in bucket_out]
    m_qs = np.zeros((B, plan.num_sectors), np.int64)
    errs = np.zeros(B)
    for b in range(B):
        m_qs[b], errs[b] = host_truncate(plan, s_host[b], k_out, max_bond, cutoff)
    keep = m_qs.max(axis=0)
    m_tuple = tuple(int(x) for x in keep)

    masks = tuple(
        jnp.asarray(np.arange(m_tuple[si])[None, :] < m_qs[:, si : si + 1])
        for si in range(plan.num_sectors)
        if m_tuple[si] > 0
    )
    slice_key = ("multi-slice", absorb_key, m_tuple)
    slice_core = plan._exec.get(slice_key)
    if slice_core is None:
        slice_core = _wrap_multi(_slice_core_body_multi(plan, m_tuple), ops)
        _cache_exec(plan, slice_key, slice_core)
    u_flat, v_flat, s_flat = slice_core(bucket_out, masks)

    new_sectors, u_blocks, v_blocks, svals = [], {}, {}, {}
    ui = vi = si_out = 0
    for si, sec in enumerate(plan.sectors):
        m = m_tuple[si]
        if m == 0:
            continue
        svals[sec.q] = s_flat[si_out]
        si_out += 1
        new_sectors.append((sec.q, m))
        for rk in sec.row_keys:
            u_blocks[(sec.q, rk)] = u_flat[ui]
            ui += 1
        for ck in sec.col_keys:
            v_blocks[(sec.q, ck)] = v_flat[vi]
            vi += 1

    bond_u = Index(tuple(new_sectors), IN, "bond")
    bond_v = Index(tuple(new_sectors), OUT, "bond")
    sector_index = {q: i for i, (q, _) in enumerate(new_sectors)}
    U_t = BlockSparseTensor(
        list(plan.row_ix) + [bond_u],
        {rk + (sector_index[q],): blk for (q, rk), blk in u_blocks.items()},
        qzero(theta.indices[0].nq),
    )
    V_t = BlockSparseTensor(
        [bond_v] + list(plan.col_ix),
        {(sector_index[q],) + ck: blk for (q, ck), blk in v_blocks.items()},
        theta.charge,
    )
    return U_t, V_t, svals, errs


def _wrap_multi(body, ops: Optional[StackedOps]):
    """jit(vmap(body)), charging (re)traces to ``ops`` when given.

    Cores live on the globally cached plan, so like the single-problem
    engines a trace is attributed to the ops instance that first compiled it.
    """

    def traced(*args):
        if ops is not None:
            ops.retraces += 1
        return body(*args)

    return jax.jit(jax.vmap(traced))


# -------------------------------------------------------------------- engine
@dataclasses.dataclass
class MultiSweepStats:
    energies: np.ndarray        # [B] final pair energy per problem
    max_bond: int               # union (batch) bond dimension
    trunc_err: np.ndarray       # [B] max truncation error per problem
    seconds: float
    davidson_seconds: float = 0.0
    svd_seconds: float = 0.0
    env_seconds: float = 0.0
    # Davidson health ledger (MultiDavidsonInfo, summed over the sweep):
    # solves run, per-problem residual convergences (converged < solves is
    # normal for budget-limited production solves), total inner iterations,
    # and Gram-Schmidt breakdown restart events
    davidson_solves: int = 0
    davidson_converged: Optional[np.ndarray] = None   # [B] counts
    davidson_iterations: int = 0
    davidson_restarts: int = 0


class MultiProblemEngine:
    """Two-site DMRG sweeps over a stacked batch of problems.

    The sweep logic mirrors ``core.sweep.DMRGEngine`` (padded operands,
    per-site padded-MPO cache, absorb-along-the-sweep splits, incremental
    envs) with every stage routed through one shared ``StackedOps`` —
    compiled callables and plan caches persist across engines/batches, which
    is what makes steady-state serving retrace-free.
    """

    def __init__(
        self,
        mps_stacked: List[BlockSparseTensor],
        mpo_stacked: List[BlockSparseTensor],
        ops: Optional[StackedOps] = None,
        davidson_iters: int = 2,
        seed: int = 0,
    ):
        assert len(mps_stacked) == len(mpo_stacked)
        self.T = mps_stacked
        self.W = mpo_stacked
        self.ops = ops if ops is not None else StackedOps()
        self.davidson_iters = davidson_iters
        self.seed = seed
        self.n = len(mps_stacked)
        self.B = batch_size(mps_stacked[0])
        self._mpo_padded: List[Optional[BlockSparseTensor]] = [None] * self.n
        self._init_envs()

    def _padded_mpo(self, j: int) -> BlockSparseTensor:
        if self._mpo_padded[j] is None:
            self._mpo_padded[j] = pad_stacked(self.W[j])
        return self._mpo_padded[j]

    def _init_envs(self):
        n, T, W = self.n, self.T, self.W
        self.left_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        self.right_envs: List[Optional[BlockSparseTensor]] = [None] * (n + 1)
        # the edge builders read only indices/dtype, so they accept stacked
        # operands; the (1,1,1) ones block is shared across the batch
        self.left_envs[0] = broadcast_tensor(left_edge(T[0], W[0]), self.B)
        self.right_envs[n - 1] = broadcast_tensor(right_edge(T[n - 1], W[n - 1]), self.B)
        for j in range(n - 2, 0, -1):
            self.right_envs[j] = self.ops.env_update(
                "right", self.right_envs[j + 1], T[j + 1], W[j + 1]
            )

    def max_bond(self) -> int:
        dims = [t.indices[2].dim for t in self.T[:-1]]
        return max(dims) if dims else 1

    def _optimize_pair(self, j: int, max_bond: int, cutoff: float, absorb: str):
        T = self.T
        theta = self.ops.contract(T[j], T[j + 1], ((2,), (0,)))
        orig_indices = theta.indices
        A = pad_stacked(self.left_envs[j])
        Bx = pad_stacked(self.right_envs[j + 1])
        theta_p = pad_stacked(theta)
        mv = self.ops.matvec_fn(A, self._padded_mpo(j), self._padded_mpo(j + 1), Bx)
        t_dav = time.perf_counter()
        lam, theta_p, dinfo = davidson_multi(
            mv, theta_p, n_iter=self.davidson_iters, seed=self.seed + j
        )
        dav_dt = time.perf_counter() - t_dav
        theta = unpad_stacked(theta_p, orig_indices)
        t_svd = time.perf_counter()
        U, V, _, errs = svd_split_multi(
            theta, 2, max_bond=max_bond, cutoff=cutoff, absorb=absorb,
            ops=self.ops,
        )
        svd_dt = time.perf_counter() - t_svd
        T[j] = flip_flow(U, 2)
        T[j + 1] = flip_flow(V, 0)
        return lam, errs, dav_dt, svd_dt, dinfo

    def sweep(self, max_bond: int, cutoff: float = 1e-12) -> MultiSweepStats:
        """One full left-to-right + right-to-left sweep over the batch."""
        n = self.n
        energies = None
        max_err = np.zeros(self.B)
        dav_secs = svd_secs = env_secs = 0.0
        solves = iters = restarts = 0
        converged = np.zeros(self.B, dtype=np.int64)
        t0 = time.perf_counter()

        def _absorb_info(dinfo: MultiDavidsonInfo):
            nonlocal solves, iters, restarts, converged
            solves += 1
            iters += dinfo.iterations
            restarts += dinfo.restarts
            converged = converged + dinfo.converged.astype(np.int64)

        for j in range(n - 1):  # left -> right
            lam, errs, dav_dt, svd_dt, dinfo = self._optimize_pair(
                j, max_bond, cutoff, absorb="right"
            )
            te = time.perf_counter()
            self.left_envs[j + 1] = self.ops.env_update(
                "left", self.left_envs[j], self.T[j], self.W[j]
            )
            env_secs += time.perf_counter() - te
            energies = lam
            max_err = np.maximum(max_err, errs)
            dav_secs += dav_dt
            svd_secs += svd_dt
            _absorb_info(dinfo)

        for j in range(n - 2, -1, -1):  # right -> left
            lam, errs, dav_dt, svd_dt, dinfo = self._optimize_pair(
                j, max_bond, cutoff, absorb="left"
            )
            te = time.perf_counter()
            self.right_envs[j] = self.ops.env_update(
                "right", self.right_envs[j + 1], self.T[j + 1], self.W[j + 1]
            )
            env_secs += time.perf_counter() - te
            energies = lam
            max_err = np.maximum(max_err, errs)
            dav_secs += dav_dt
            svd_secs += svd_dt
            _absorb_info(dinfo)

        return MultiSweepStats(
            energies=energies,
            max_bond=self.max_bond(),
            trunc_err=max_err,
            seconds=time.perf_counter() - t0,
            davidson_seconds=dav_secs,
            svd_seconds=svd_secs,
            env_seconds=env_secs,
            davidson_solves=solves,
            davidson_converged=converged,
            davidson_iterations=iters,
            davidson_restarts=restarts,
        )


@dataclasses.dataclass
class MultiDMRGResult:
    energies: np.ndarray                 # [B] final sweep energies
    sweep_stats: List[MultiSweepStats]
    engine: MultiProblemEngine


def run_dmrg_multi(
    space,
    n_sites: int,
    mpos: Sequence[Sequence[BlockSparseTensor]],
    bond_schedule: Sequence[int] = (8, 16, 32),
    sweeps_per_bond: int = 2,
    cutoff: float = 1e-12,
    davidson_iters: int = 3,
    initial_states: Optional[Sequence[int]] = None,
    dtype=jnp.float64,
    ops: Optional[StackedOps] = None,
) -> MultiDMRGResult:
    """``core.dmrg.run_dmrg`` over B structure-identical problems at once.

    ``mpos`` is one pre-built (compressed) MPO per problem; all must share
    one structure signature — the scheduler groups requests so this holds,
    and it is asserted here because a violation would silently corrupt every
    problem in the batch.  Pass a shared ``ops`` to reuse compiled pipelines
    across calls (the serving path always does).
    """
    sig0 = mpo_structure_signature(mpos[0])
    for mp in mpos[1:]:
        if mpo_structure_signature(mp) != sig0:
            raise ValueError(
                "run_dmrg_multi: MPO structure mismatch across the batch; "
                "problems with different block structures cannot share a "
                "vmapped pipeline (group by mpo_structure_signature first)"
            )
    W = [stack_tensors([mp[j] for mp in mpos]) for j in range(n_sites)]
    states = (
        list(initial_states) if initial_states is not None
        else neel_states(space, n_sites)
    )
    mps0 = product_state_mps(space, states, dtype=dtype)
    T = [broadcast_tensor(t, len(mpos)) for t in mps0.tensors]
    engine = MultiProblemEngine(
        T, W, ops=ops, davidson_iters=davidson_iters
    )
    stats: List[MultiSweepStats] = []
    for m in bond_schedule:
        for _ in range(sweeps_per_bond):
            stats.append(engine.sweep(max_bond=m, cutoff=cutoff))
    return MultiDMRGResult(
        energies=stats[-1].energies, sweep_stats=stats, engine=engine
    )
