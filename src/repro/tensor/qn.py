"""U(1)^n quantum-number (charge) machinery.

The paper (Levy/Solomonik/Clark 2020, Sec. II-D) restricts to abelian U(1)
symmetries: total S_z for the spin system and (particle number, 2*S_z) for the
electron system.  A charge is a tuple of integers; composition is element-wise
addition.  Every tensor index carries a list of (charge, degeneracy) sectors
and a *flow* (+1 outgoing / -1 incoming); a block is nonzero only when

    sum_i flow_i * charge_i == tensor.charge      (element-wise).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

Charge = Tuple[int, ...]

OUT = +1  # flow: charge leaves the tensor along this index
IN = -1   # flow: charge enters the tensor along this index


def qadd(a: Charge, b: Charge) -> Charge:
    return tuple(x + y for x, y in zip(a, b))


def qneg(a: Charge) -> Charge:
    return tuple(-x for x in a)


def qscale(a: Charge, s: int) -> Charge:
    return tuple(s * x for x in a)


def qzero(nq: int) -> Charge:
    return (0,) * nq


@dataclasses.dataclass(frozen=True)
class Index:
    """A tensor mode: ordered charge sectors with degeneracies and a flow.

    ``sectors`` is a tuple of (charge, dim) with distinct charges; the dense
    dimension of the mode is ``sum(dim)``.  Two indices can be contracted iff
    they have identical sectors and opposite flows.
    """

    sectors: Tuple[Tuple[Charge, int], ...]
    flow: int = OUT
    name: str = dataclasses.field(default="", compare=False)

    def __post_init__(self):
        assert self.flow in (OUT, IN)
        charges = [q for q, _ in self.sectors]
        assert len(set(charges)) == len(charges), f"duplicate charges: {charges}"
        assert all(d > 0 for _, d in self.sectors)

    # -- basic queries ------------------------------------------------------
    @property
    def nq(self) -> int:
        return len(self.sectors[0][0])

    @property
    def dim(self) -> int:
        return sum(d for _, d in self.sectors)

    @property
    def num_sectors(self) -> int:
        return len(self.sectors)

    def charge(self, s: int) -> Charge:
        return self.sectors[s][0]

    def sector_dim(self, s: int) -> int:
        return self.sectors[s][1]

    def sector_of(self, q: Charge) -> int:
        for i, (qi, _) in enumerate(self.sectors):
            if qi == q:
                return i
        raise KeyError(q)

    def offsets(self) -> Tuple[int, ...]:
        """Dense offset of each sector when blocks are embedded densely."""
        out, acc = [], 0
        for _, d in self.sectors:
            out.append(acc)
            acc += d
        return tuple(out)

    # -- algebra ------------------------------------------------------------
    def dual(self) -> "Index":
        """Same sectors, opposite flow (for contraction partners)."""
        return Index(self.sectors, -self.flow, self.name + "*")

    def with_flow(self, flow: int) -> "Index":
        return Index(self.sectors, flow, self.name)

    def can_contract(self, other: "Index") -> bool:
        return self.sectors == other.sectors and self.flow == -other.flow


def fuse_sectors(
    indices: Sequence[Index], signs: Sequence[int] | None = None
) -> dict:
    """Map fused charge -> list of (sector-position tuple, dims tuple).

    ``signs[i]`` multiplies the flow of index i (used to orient row vs column
    groups when matricizing).  The fused charge of a sector combination is
    sum_i signs[i]*flow_i*charge_i.
    """
    if signs is None:
        signs = [1] * len(indices)
    nq = indices[0].nq
    table: dict = {}

    def rec(i: int, q: Charge, pos: tuple, dims: tuple):
        if i == len(indices):
            table.setdefault(q, []).append((pos, dims))
            return
        idx = indices[i]
        for s, (qs, d) in enumerate(idx.sectors):
            rec(i + 1, qadd(q, qscale(qs, signs[i] * idx.flow)), pos + (s,), dims + (d,))

    rec(0, qzero(nq), (), ())
    return table


def make_index(sector_dims: Iterable[Tuple[Charge, int]], flow: int = OUT, name: str = "") -> Index:
    return Index(tuple(sector_dims), flow, name)
