"""Block-sparse distributed tensor substrate (the Cyclops analogue in JAX)."""
from .qn import Charge, IN, Index, OUT, fuse_sectors, make_index, qadd, qneg, qzero
from .blocksparse import (
    BlockSparseTensor,
    contract,
    contract_dense,
    svd_split,
    svd_split_unplanned,
)
from .block_csr import contract_block_csr

__all__ = [
    "Charge", "IN", "Index", "OUT", "fuse_sectors", "make_index", "qadd",
    "qneg", "qzero", "BlockSparseTensor", "contract", "contract_dense",
    "svd_split", "svd_split_unplanned", "contract_block_csr",
]
