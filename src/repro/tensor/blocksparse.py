"""Block-sparse tensors and the *list* contraction algorithm (paper Alg. 2).

A ``BlockSparseTensor`` stores one dense array per nonzero quantum-number
block, exactly as the paper's list format stores "a set of memory distributed
tensor blocks T_{q^(l)}".  On TPU, each block array is a ``jax.Array`` that may
itself be sharded over the full device mesh by the caller — this mirrors the
paper's key decision to distribute *every block over all processors* instead
of assigning blocks to nodes (which load-imbalances because the largest block
scales ~ m, their Fig. 2a).

The class is registered as a pytree so whole DMRG sweep steps jit cleanly;
the block keys / index metadata are static, the block arrays are leaves.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .qn import Charge, IN, Index, OUT, qadd, qscale, qzero

BlockKey = Tuple[int, ...]  # sector position along each mode


class BlockSparseTensor:
    """List-format block-sparse tensor (paper Sec. IV-A, "list algorithm")."""

    def __init__(
        self,
        indices: Sequence[Index],
        blocks: Dict[BlockKey, jax.Array],
        charge: Charge | None = None,
    ):
        self.indices = tuple(indices)
        self.charge = charge if charge is not None else qzero(self.indices[0].nq)
        self.blocks = dict(blocks)

    # ------------------------------------------------------------------ meta
    @property
    def ndim(self) -> int:
        return len(self.indices)

    @property
    def dtype(self):
        for b in self.blocks.values():
            return b.dtype
        return jnp.float64

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(ix.dim for ix in self.indices)

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    @property
    def nnz(self) -> int:
        return sum(int(np.prod(b.shape)) for b in self.blocks.values())

    def block_shape(self, key: BlockKey) -> Tuple[int, ...]:
        return tuple(ix.sector_dim(s) for ix, s in zip(self.indices, key))

    def key_charge(self, key: BlockKey) -> Charge:
        q = qzero(self.indices[0].nq)
        for ix, s in zip(self.indices, key):
            q = qadd(q, qscale(ix.charge(s), ix.flow))
        return q

    def is_valid_key(self, key: BlockKey) -> bool:
        return self.key_charge(key) == self.charge

    def valid_keys(self) -> List[BlockKey]:
        """All sector combinations consistent with the tensor charge."""
        out: List[BlockKey] = []

        def rec(i: int, q: Charge, key: BlockKey):
            if i == len(self.indices):
                if q == self.charge:
                    out.append(key)
                return
            ix = self.indices[i]
            for s in range(ix.num_sectors):
                rec(i + 1, qadd(q, qscale(ix.charge(s), ix.flow)), key + (s,))

        rec(0, qzero(self.indices[0].nq), ())
        return out

    def check(self):
        for k, b in self.blocks.items():
            assert self.is_valid_key(k), f"block {k} violates charge conservation"
            assert tuple(b.shape) == self.block_shape(k), (
                f"block {k} shape {b.shape} != {self.block_shape(k)}"
            )

    # ------------------------------------------------------------- construct
    @staticmethod
    def zeros(indices: Sequence[Index], charge: Charge | None = None, dtype=jnp.float64):
        t = BlockSparseTensor(indices, {}, charge)
        t.blocks = {k: jnp.zeros(t.block_shape(k), dtype) for k in t.valid_keys()}
        return t

    @staticmethod
    def random(
        indices: Sequence[Index],
        charge: Charge | None = None,
        key: jax.Array | None = None,
        dtype=jnp.float64,
    ):
        t = BlockSparseTensor(indices, {}, charge)
        key = key if key is not None else jax.random.PRNGKey(0)
        blocks = {}
        for k in t.valid_keys():
            key, sub = jax.random.split(key)
            blocks[k] = jax.random.normal(sub, t.block_shape(k), dtype)
        t.blocks = blocks
        return t

    # --------------------------------------------------------------- algebra
    def scale(self, a) -> "BlockSparseTensor":
        return BlockSparseTensor(self.indices, {k: a * b for k, b in self.blocks.items()}, self.charge)

    def __mul__(self, a):
        return self.scale(a)

    __rmul__ = __mul__

    def __add__(self, other: "BlockSparseTensor") -> "BlockSparseTensor":
        assert self.indices == other.indices and self.charge == other.charge
        blocks = dict(self.blocks)
        for k, b in other.blocks.items():
            blocks[k] = blocks[k] + b if k in blocks else b
        return BlockSparseTensor(self.indices, blocks, self.charge)

    def __sub__(self, other: "BlockSparseTensor") -> "BlockSparseTensor":
        return self + other.scale(-1.0)

    def conj(self) -> "BlockSparseTensor":
        """Complex conjugate + flip all flows (bra tensor)."""
        return BlockSparseTensor(
            [ix.dual() for ix in self.indices],
            {k: jnp.conj(b) for k, b in self.blocks.items()},
            qscale(self.charge, -1),
        )

    def transpose(self, perm: Sequence[int]) -> "BlockSparseTensor":
        perm = tuple(perm)
        return BlockSparseTensor(
            [self.indices[p] for p in perm],
            {tuple(k[p] for p in perm): jnp.transpose(b, perm) for k, b in self.blocks.items()},
            self.charge,
        )

    def norm_sq(self):
        acc = 0.0
        for b in self.blocks.values():
            acc = acc + jnp.sum(jnp.abs(b) ** 2)
        return jnp.real(acc)

    def norm(self):
        return jnp.sqrt(self.norm_sq())

    def inner(self, other: "BlockSparseTensor"):
        """<self|other> = sum over shared blocks of conj(self).other."""
        acc = 0.0
        for k, b in self.blocks.items():
            if k in other.blocks:
                acc = acc + jnp.sum(jnp.conj(b) * other.blocks[k])
        return acc

    # ------------------------------------------------------------- densify
    def to_dense(self) -> jax.Array:
        """Embed blocks at sector offsets (the sparse-dense layout)."""
        out = jnp.zeros(self.shape, self.dtype)
        offs = [ix.offsets() for ix in self.indices]
        for k, b in self.blocks.items():
            sl = tuple(
                slice(offs[i][s], offs[i][s] + self.indices[i].sector_dim(s))
                for i, s in enumerate(k)
            )
            out = out.at[sl].set(b)
        return out

    @staticmethod
    def from_dense(
        dense: jax.Array, indices: Sequence[Index], charge: Charge | None = None
    ) -> "BlockSparseTensor":
        t = BlockSparseTensor(indices, {}, charge)
        offs = [ix.offsets() for ix in indices]
        blocks = {}
        for k in t.valid_keys():
            sl = tuple(
                slice(offs[i][s], offs[i][s] + indices[i].sector_dim(s))
                for i, s in enumerate(k)
            )
            blocks[k] = dense[sl]
        t.blocks = blocks
        return t


# --------------------------------------------------------------------- pytree
def _bst_flatten(t: BlockSparseTensor):
    keys = tuple(sorted(t.blocks.keys()))
    children = tuple(t.blocks[k] for k in keys)
    aux = (t.indices, t.charge, keys)
    return children, aux


def _bst_unflatten(aux, children) -> BlockSparseTensor:
    indices, charge, keys = aux
    return BlockSparseTensor(indices, dict(zip(keys, children)), charge)


jax.tree_util.register_pytree_node(BlockSparseTensor, _bst_flatten, _bst_unflatten)


def flip_flow(t: BlockSparseTensor, axis: int) -> BlockSparseTensor:
    """Replace Index(q, flow) with Index(-q, -flow) on one mode (no-op on data).

    flow*q is invariant, so charge conservation is untouched; used to
    re-orient bond arrows after ``svd_split`` (e.g. MPO compression keeps
    l: IN / r: OUT).  Both sides of a bond must be flipped together.
    """
    ix = t.indices[axis]
    perm = sorted(range(ix.num_sectors), key=lambda s: tuple(-c for c in ix.charge(s)))
    new_ix = Index(
        tuple((tuple(-c for c in ix.charge(s)), ix.sector_dim(s)) for s in perm),
        -ix.flow,
        ix.name,
    )
    inv = {old: new for new, old in enumerate(perm)}
    blocks = {
        k[:axis] + (inv[k[axis]],) + k[axis + 1 :]: b for k, b in t.blocks.items()
    }
    indices = list(t.indices)
    indices[axis] = new_ix
    return BlockSparseTensor(indices, blocks, t.charge)


# ------------------------------------------------------------------ contract
def contract(
    a: BlockSparseTensor,
    b: BlockSparseTensor,
    axes: Tuple[Sequence[int], Sequence[int]],
) -> BlockSparseTensor:
    """Paper Algorithm 2: list-format block-sparse contraction.

    Enumerates all block pairs whose charges match along the contracted modes
    and tensordot-s them, accumulating into output blocks keyed by the
    remaining sector labels.  Under ``jit`` the Python loop unrolls into one
    XLA graph, so independent block GEMMs overlap (the TPU analogue of the
    paper's O(N_b) BSP supersteps collapsing into one program).

    This is the reference algorithm every other backend (dense, csr,
    batched, and the plan-executed engine paths) is tested against: all of
    them must reproduce its output blocks to <=1e-12 on random charged
    tensors and DMRG energies to <1e-10.
    """
    ax_a, ax_b = tuple(axes[0]), tuple(axes[1])
    assert len(ax_a) == len(ax_b)
    for ia, ib in zip(ax_a, ax_b):
        assert a.indices[ia].can_contract(b.indices[ib]), (
            f"mode {ia} of A cannot contract mode {ib} of B: "
            f"{a.indices[ia]} vs {b.indices[ib]}"
        )
    keep_a = [i for i in range(a.ndim) if i not in ax_a]
    keep_b = [i for i in range(b.ndim) if i not in ax_b]
    out_indices = [a.indices[i] for i in keep_a] + [b.indices[i] for i in keep_b]
    out_charge = qadd(a.charge, b.charge)

    # index B blocks by their contracted-sector signature (hash join, not the
    # O(N_a * N_b) double loop in the paper's pseudocode)
    b_by_sig: Dict[Tuple[int, ...], List[BlockKey]] = {}
    for kb in b.blocks:
        sig = tuple(kb[i] for i in ax_b)
        b_by_sig.setdefault(sig, []).append(kb)

    out_blocks: Dict[BlockKey, jax.Array] = {}
    for ka, ablock in a.blocks.items():
        sig = tuple(ka[i] for i in ax_a)
        for kb in b_by_sig.get(sig, ()):  # matching quantum-number labels
            kc = tuple(ka[i] for i in keep_a) + tuple(kb[i] for i in keep_b)
            piece = jnp.tensordot(ablock, b.blocks[kb], axes=(ax_a, ax_b))
            if kc in out_blocks:
                out_blocks[kc] = out_blocks[kc] + piece
            else:
                out_blocks[kc] = piece

    out = BlockSparseTensor(out_indices, out_blocks, out_charge)
    return out


def contract_dense(
    a: BlockSparseTensor,
    b: BlockSparseTensor,
    axes: Tuple[Sequence[int], Sequence[int]],
) -> BlockSparseTensor:
    """Paper's *sparse-dense* algorithm: embed into dense, single tensordot.

    Storage cost rises to prod(dims) per tensor (paper: "each MPS tensor now
    has storage cost d m^2, the same as without quantum numbers") but the
    contraction is one dense GEMM that runs at MXU speed.  The embedding is a
    contraction homomorphism — mismatched blocks land on zeros — so the result
    equals the list algorithm exactly; we re-extract only charge-legal blocks.
    """
    ax_a, ax_b = tuple(axes[0]), tuple(axes[1])
    keep_a = [i for i in range(a.ndim) if i not in ax_a]
    keep_b = [i for i in range(b.ndim) if i not in ax_b]
    out_indices = [a.indices[i] for i in keep_a] + [b.indices[i] for i in keep_b]
    dense = jnp.tensordot(a.to_dense(), b.to_dense(), axes=(ax_a, ax_b))
    return BlockSparseTensor.from_dense(dense, out_indices, qadd(a.charge, b.charge))


# ------------------------------------------------------------------ SVD split
def svd_split(
    theta: BlockSparseTensor,
    n_row_modes: int,
    max_bond: int,
    cutoff: float = 1e-12,
    absorb: str = "right",
):
    """Blockwise truncated SVD across a bond (paper Fig. 1e, Sec. IV-A).

    Planned front door: delegates to the shape-bucketed batched engine in
    ``dist/decomp.py`` (one gather-assembled batched ``jnp.linalg.svd`` per
    padded sector-shape bucket, one host sync per call).  The seed per-sector
    loop remains available as ``svd_split_unplanned``; the planned path
    matches it to <1e-10 up to the per-singular-vector sign gauge (products
    U·V, singular values, retained sectors and ``trunc_err`` agree
    unconditionally), except on *exact* singular-value ties at the truncation
    threshold, where the planned path breaks ties deterministically by
    (sector charge, position) and keeps the total bond ≤ ``max_bond`` while
    the seed path keeps every tied value (and can exceed ``max_bond``).

    Semantics (both paths): ``theta`` is matricized with the first
    ``n_row_modes`` modes as rows, blocks are grouped by the fused row
    charge, each charge sector is SVD'd, and truncation is *global* across
    sectors — keep at most ``max_bond`` values, dropping those ``<= cutoff *
    s_max`` (the comparison is strict ``>`` for keeping); at least one value
    is always kept.  ``absorb`` multiplies the retained singular values into
    U ("left") or V ("right"); any other string leaves both isometric
    (singular values absorbed into neither).

    Returns ``(U_tensor, V_tensor, svals_by_sector, trunc_err)`` with the
    new bond index carrying one sector per retained charge and ``trunc_err``
    the sum of squared discarded singular values (= the squared Frobenius
    reconstruction error of the absorbed product U·V).  Must be called with
    concrete (non-tracer) blocks: truncation syncs singular values to host.
    """
    from ..dist.decomp import svd_split_planned  # lazy: tensor -> dist only here

    return svd_split_planned(
        theta, n_row_modes, max_bond, cutoff=cutoff, absorb=absorb
    )


def svd_split_unplanned(
    theta: BlockSparseTensor,
    n_row_modes: int,
    max_bond: int,
    cutoff: float = 1e-12,
    absorb: str = "right",
):
    """Seed blockwise truncated SVD: the per-sector loop, kept for A/B.

    Matricizes ``theta`` with the first ``n_row_modes`` modes as rows, groups
    blocks by the fused charge across the cut, SVDs each charge sector
    independently (one dense assembly + one ``jnp.linalg.svd`` + one host
    sync per sector), then truncates *globally* by singular value, exactly
    like the paper's list-format SVD ("grouped via similar quantum numbers
    along a row or column index, and decomposed").

    Tie-break semantics this implementation actually has: the global
    threshold is the ``n_keep``-th largest value with ``n_keep =
    min(max_bond, #values > cutoff * s_max)``, and each sector keeps every
    value ``>= thresh`` (capped at ``n_keep`` per sector) — so *exact* ties
    at the threshold across sectors are all kept and the total retained bond
    can exceed ``max_bond``; ``trunc_err`` is always the tail sum beyond the
    top ``n_keep`` regardless.  ``absorb`` scales U ("left") or V ("right");
    any other string scales neither.  See ``svd_split`` for the planned
    batched path and its equality guarantee.

    Returns (U_tensor, V_tensor, svals_by_sector, trunc_err) with the
    singular values absorbed into U ("left") or V ("right") following the
    sweep direction, and the new bond index carrying one sector per retained
    charge.
    """
    if not theta.blocks:
        raise ValueError("svd_split of a tensor with no blocks")
    row_ix = theta.indices[:n_row_modes]
    col_ix = theta.indices[n_row_modes:]

    # group blocks by fused row charge q (flow OUT along the new bond)
    groups: Dict[Charge, List[BlockKey]] = {}
    for k in theta.blocks:
        q = qzero(theta.indices[0].nq)
        for ix, s in zip(row_ix, k[:n_row_modes]):
            q = qadd(q, qscale(ix.charge(s), ix.flow))
        groups.setdefault(q, []).append(k)

    # per charge sector: assemble dense matrix [sum(row dims), sum(col dims)]
    sector_data = []  # (q, U, S, Vh, row_layout, col_layout)
    for q, keys in sorted(groups.items()):
        row_keys = sorted({k[:n_row_modes] for k in keys})
        col_keys = sorted({k[n_row_modes:] for k in keys})
        rdim = {rk: int(np.prod([ix.sector_dim(s) for ix, s in zip(row_ix, rk)] or [1])) for rk in row_keys}
        cdim = {ck: int(np.prod([ix.sector_dim(s) for ix, s in zip(col_ix, ck)] or [1])) for ck in col_keys}
        roff, acc = {}, 0
        for rk in row_keys:
            roff[rk] = acc
            acc += rdim[rk]
        R = acc
        coff, acc = {}, 0
        for ck in col_keys:
            coff[ck] = acc
            acc += cdim[ck]
        C = acc
        mat = jnp.zeros((R, C), theta.dtype)
        for k in keys:
            rk, ck = k[:n_row_modes], k[n_row_modes:]
            blk = theta.blocks[k].reshape(rdim[rk], cdim[ck])
            mat = mat.at[roff[rk] : roff[rk] + rdim[rk], coff[ck] : coff[ck] + cdim[ck]].set(blk)
        U, S, Vh = jnp.linalg.svd(mat, full_matrices=False)
        sector_data.append((q, U, S, Vh, (row_keys, rdim, roff), (col_keys, cdim, coff)))

    # global truncation across sectors (concretizes: SVD sizes are data-dep)
    all_s = np.concatenate([np.asarray(S) for _, _, S, _, _, _ in sector_data])
    order = np.argsort(all_s)[::-1]
    smax = float(all_s[order[0]]) if len(order) else 1.0
    keep_vals = all_s[order]
    n_keep = int(min(max_bond, np.sum(keep_vals > cutoff * smax)))
    n_keep = max(n_keep, 1)
    thresh = keep_vals[n_keep - 1]
    trunc_err = float(np.sum(keep_vals[n_keep:] ** 2))

    new_sectors, u_blocks, v_blocks, svals = [], {}, {}, {}
    for q, U, S, Vh, (row_keys, rdim, roff), (col_keys, cdim, coff) in sector_data:
        m_q = int(np.sum(np.asarray(S) >= thresh))
        m_q = min(m_q, n_keep)  # guard exact ties
        if m_q == 0:
            continue
        Uq, Sq, Vq = U[:, :m_q], S[:m_q], Vh[:m_q, :]
        if absorb == "right":
            Vq = Sq[:, None] * Vq
        elif absorb == "left":
            Uq = Uq * Sq[None, :]
        svals[q] = Sq
        new_sectors.append((q, m_q))
        for rk in row_keys:
            shp = tuple(ix.sector_dim(s) for ix, s in zip(row_ix, rk)) + (m_q,)
            u_blocks[(q, rk)] = Uq[roff[rk] : roff[rk] + rdim[rk], :].reshape(shp)
        for ck in col_keys:
            shp = (m_q,) + tuple(ix.sector_dim(s) for ix, s in zip(col_ix, ck))
            v_blocks[(q, ck)] = Vq[:, coff[ck] : coff[ck] + cdim[ck]].reshape(shp)

    # New bond carries the fused row charge q: on U it flows IN
    # (row-charge q + IN*q = 0 = U.charge), on V it flows OUT
    # (OUT*q + col-charge (Q - q) = Q = theta.charge); IN/OUT are contractible.
    bond_u = Index(tuple(new_sectors), IN, "bond")
    bond_v = Index(tuple(new_sectors), OUT, "bond")
    sector_index = {q: i for i, (q, _) in enumerate(new_sectors)}

    U_t = BlockSparseTensor(
        list(row_ix) + [bond_u],
        {rk + (sector_index[q],): b for (q, rk), b in u_blocks.items()},
        qzero(theta.indices[0].nq),
    )
    V_t = BlockSparseTensor(
        [bond_v] + list(col_ix),
        {(sector_index[q],) + ck: b for (q, ck), b in v_blocks.items()},
        theta.charge,
    )
    return U_t, V_t, svals, trunc_err
