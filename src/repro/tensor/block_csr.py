"""Sparse-sparse contraction, TPU-adapted: block-CSR batched GEMM.

The paper's sparse-sparse algorithm stores whole tensors as one distributed
element-sparse CTF tensor, pre-computing the output sparsity from the quantum
numbers.  TPUs have no efficient element-sparse GEMM, so the adaptation (see
DESIGN.md Sec. 2) keeps sparsity at *block* granularity: matricize each
quantum-number block, pack all blocks of each operand into one padded batched
array, pre-compute the (lhs, rhs) -> out pair table from the charges (the
analogue of CTF's output-sparsity precomputation), and execute a single Pallas
batched block-sparse GEMM — one kernel launch == the paper's O(1) supersteps.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels.block_gemm.ops import block_sparse_matmul
from .blocksparse import BlockKey, BlockSparseTensor
from .qn import qadd


def pack_blocks(
    t: BlockSparseTensor,
    keys: Sequence[BlockKey],
    keep: Sequence[int],
    ax: Sequence[int],
    rdim: int,
    cdim: int,
    keep_first: bool,
) -> jnp.ndarray:
    """Matricize + zero-pad blocks into one [len(keys), rdim, cdim] batch.

    ``keep_first`` puts the kept modes on rows (lhs layout) vs the contracted
    modes on rows (rhs layout).  Shared by the seed csr contraction below and
    the plan-executed csr backend in dist/engine.py so the padding/transpose
    conventions cannot diverge.
    """
    keep, ax = list(keep), list(ax)
    out = []
    for k in keys:
        blk = t.blocks[k]
        perm = keep + ax if keep_first else ax + keep
        blk = jnp.transpose(blk, perm)
        r = int(np.prod([t.indices[i].sector_dim(k[i]) for i in (keep if keep_first else ax)] or [1]))
        c = int(np.prod([t.indices[i].sector_dim(k[i]) for i in (ax if keep_first else keep)] or [1]))
        blk = blk.reshape(r, c)
        blk = jnp.pad(blk, ((0, (rdim - r)), (0, (cdim - c))))
        out.append(blk)
    return jnp.stack(out)


def contract_block_csr(
    a: BlockSparseTensor,
    b: BlockSparseTensor,
    axes: Tuple[Sequence[int], Sequence[int]],
    *,
    interpret: bool = False,
    use_kernel: bool = True,
) -> BlockSparseTensor:
    """Contract via one batched block-sparse GEMM (sparse-sparse analogue).

    Backend-equality guarantee: zero-padding is exact for GEMMs, so the
    result equals the list algorithm (``contract``) block-for-block to
    machine precision — the padded rows/columns multiply into zeros and the
    unpadded region is sliced back out (asserted at <=1e-12 in
    tests/test_dist.py and tests/test_kernels.py).
    """
    ax_a, ax_b = tuple(axes[0]), tuple(axes[1])
    keep_a = [i for i in range(a.ndim) if i not in ax_a]
    keep_b = [i for i in range(b.ndim) if i not in ax_b]
    out_indices = [a.indices[i] for i in keep_a] + [b.indices[i] for i in keep_b]
    out_charge = qadd(a.charge, b.charge)

    a_keys = sorted(a.blocks.keys())
    b_keys = sorted(b.blocks.keys())
    a_pos = {k: i for i, k in enumerate(a_keys)}
    b_pos = {k: i for i, k in enumerate(b_keys)}

    # matricized per-block shapes
    def mshape(t, key, keep, ax):
        rows = int(np.prod([t.indices[i].sector_dim(key[i]) for i in keep] or [1]))
        cols = int(np.prod([t.indices[i].sector_dim(key[i]) for i in ax] or [1]))
        return rows, cols

    # pair table from quantum numbers (precomputed output sparsity)
    b_by_sig: Dict[Tuple[int, ...], List[BlockKey]] = {}
    for kb in b_keys:
        b_by_sig.setdefault(tuple(kb[i] for i in ax_b), []).append(kb)

    out_keys: List[BlockKey] = []
    out_pos: Dict[BlockKey, int] = {}
    pairs: List[Tuple[int, int, int]] = []
    for ka in a_keys:
        sig = tuple(ka[i] for i in ax_a)
        for kb in b_by_sig.get(sig, ()):
            kc = tuple(ka[i] for i in keep_a) + tuple(kb[i] for i in keep_b)
            if kc not in out_pos:
                out_pos[kc] = len(out_keys)
                out_keys.append(kc)
            pairs.append((a_pos[ka], b_pos[kb], out_pos[kc]))
    if not pairs:
        return BlockSparseTensor(out_indices, {}, out_charge)

    # renumber output blocks in pair-sorted order so out_idx is ascending
    pairs.sort(key=lambda t: t[2])

    # pack operands: pad every PARTICIPATING matricized block to the max
    # (BM, BK) / (BK, BN); non-participating blocks multiply a zero sector
    # and are skipped by the pair table
    part_a = sorted({p[0] for p in pairs})
    part_b = sorted({p[1] for p in pairs})
    BM = max(mshape(a, a_keys[i], keep_a, ax_a)[0] for i in part_a)
    BK = max(
        max(mshape(a, a_keys[i], keep_a, ax_a)[1] for i in part_a),
        max(mshape(b, b_keys[i], keep_b, ax_b)[1] for i in part_b),
    )
    BN = max(mshape(b, b_keys[i], keep_b, ax_b)[0] for i in part_b)

    a_remap = {i: n for n, i in enumerate(part_a)}
    b_remap = {i: n for n, i in enumerate(part_b)}
    lhs_all = pack_blocks(a, [a_keys[i] for i in part_a], keep_a, ax_a, BM, BK, True)   # [Na', BM, BK]
    rhs_all = pack_blocks(b, [b_keys[i] for i in part_b], keep_b, ax_b, BK, BN, False)  # [Nb', BK, BN]

    li = jnp.array([a_remap[p[0]] for p in pairs], jnp.int32)
    ri = jnp.array([b_remap[p[1]] for p in pairs], jnp.int32)
    oi = jnp.array([p[2] for p in pairs], jnp.int32)
    lhs = lhs_all[li]
    rhs = rhs_all[ri]

    out_padded = block_sparse_matmul(
        lhs, rhs, oi, len(out_keys), interpret=interpret, use_kernel=use_kernel
    )

    # unpack: slice padding off and reshape to block shapes
    out_blocks: Dict[BlockKey, jnp.ndarray] = {}
    for kc, o in out_pos.items():
        shp = tuple(ix.sector_dim(s) for ix, s in zip(out_indices, kc))
        r = int(np.prod([out_indices[i].sector_dim(kc[i]) for i in range(len(keep_a))] or [1]))
        c = int(np.prod([out_indices[i].sector_dim(kc[i]) for i in range(len(keep_a), len(out_indices))] or [1]))
        out_blocks[kc] = out_padded[o, :r, :c].reshape(shp)
    return BlockSparseTensor(out_indices, out_blocks, out_charge)
