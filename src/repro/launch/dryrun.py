import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes, record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md).

The two lines above run before ANY other import — jax locks the device count
at first init.  Each cell writes artifacts/dryrun/<arch>_<shape>_<mesh>.json;
completed cells are skipped unless --force.

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --dmrg           # the paper's DMRG cells
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# ring-algorithm wire-byte multipliers applied to the HLO result shape
_COLL_RE = re.compile(
    r"=\s+((?:\(|)[a-z0-9](?:[^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip wire bytes by collective type, from the post-SPMD HLO.

    Ring-model multipliers on the op's RESULT bytes R with group size G:
      all-gather:          R * (G-1)/G      (each chip receives the rest)
      all-reduce:          2R * (G-1)/G     (reduce-scatter + all-gather)
      reduce-scatter:      R * (G-1)        (input = R*G, sends (G-1)/G of it)
      all-to-all:          R * (G-1)/G
      collective-permute:  R
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        r = _shape_bytes(shape_str)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))  # [n_groups, group_size]
        else:
            gb = _GROUPS_BRACES_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if g <= 1 and op != "collective-permute":
            continue
        if op == "all-gather":
            wire = r * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * r * (g - 1) / g
        elif op == "reduce-scatter":
            wire = r * (g - 1)
        elif op == "all-to-all":
            wire = r * (g - 1) / g
        else:  # collective-permute
            wire = float(r)
        out[op] += wire
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    return out


def model_flops_estimate(arch: str, shape_name: str) -> float:
    """6*N*D for train (N = active params, D = tokens); 2*N*D for fwd-only;
    decode: 2*N per token * batch (one step)."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    info = SHAPES[shape_name]
    n = cfg.active_param_count()
    if info["kind"] == "train":
        return 6.0 * n * info["global_batch"] * info["seq_len"]
    if info["kind"] == "prefill":
        return 2.0 * n * info["global_batch"] * info["seq_len"]
    return 2.0 * n * info["global_batch"]  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False) -> dict:
    mesh_name = "pod512" if multi_pod else "pod256"
    out_path = out_dir / f"{arch}_{shape_name}_{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    import jax
    from repro.configs import get_config
    from repro.launch.mesh import HW, make_production_mesh, mesh_context
    from repro.launch import specs

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_name, chips=n_chips)

    if arch.endswith("_list"):
        fn, args, in_sh, out_sh, donate = specs.dmrg_list_cell(arch, mesh)
    elif arch.startswith("dmrg"):
        fn, args, in_sh, out_sh, donate = specs.dmrg_cell(arch, mesh)
    else:
        cfg = get_config(arch)
        ok, why = cfg.shape_supported(shape_name)
        if not ok:
            rec.update(status="skipped", reason=why)
            out_path.write_text(json.dumps(rec, indent=1))
            return rec
        fn, args, in_sh, out_sh, donate = specs.lm_cell(arch, shape_name, mesh)

    with mesh_context(mesh):
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, list) else cost
        hlo = compiled.as_text()

    # while-trip-aware per-chip costs (XLA cost_analysis counts loop bodies
    # once — see launch/hlo_costs.py); keep the raw numbers for reference
    from repro.launch.hlo_costs import total_costs

    tc = total_costs(hlo)
    coll = dict(tc["coll"])
    coll["count"] = 0
    coll["total"] = tc["coll_total"]

    flops_per_chip = float(tc["flops"])
    bytes_per_chip = float(tc["bytes"])
    compute_term = flops_per_chip / HW["peak_flops_bf16"]
    memory_term = bytes_per_chip / HW["hbm_bw"]
    collective_term = coll["total"] / HW["ici_bw"]
    terms = dict(compute=compute_term, memory=memory_term,
                 collective=collective_term)
    dominant = max(terms, key=terms.get)

    mf = 0.0 if arch.startswith("dmrg") else model_flops_estimate(arch, shape_name)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_bytes=mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
            hbm_bytes=HW["hbm_bytes"],
        ),
        flops_per_chip=flops_per_chip,
        bytes_per_chip=bytes_per_chip,
        xla_flops_per_chip=float(cost.get("flops", 0.0)),       # loop-body-once
        xla_bytes_per_chip=float(cost.get("bytes accessed", 0.0)),
        collective=coll,
        roofline=dict(
            compute_s=compute_term,
            memory_s=memory_term,
            collective_s=collective_term,
            dominant=dominant,
            step_s_lower_bound=max(terms.values()),
        ),
        model_flops_global=mf,
        model_flops_per_chip=mf / n_chips,
        useful_flops_ratio=(mf / n_chips / flops_per_chip) if flops_per_chip else 0.0,
    )
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def all_cells(include_dmrg: bool = True):
    from repro.configs import ARCH_IDS, SHAPES, get_config

    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            cells.append((arch, shape))
    if include_dmrg:
        for name in ("dmrg_spins", "dmrg_electrons", "dmrg_spins_opt",
                     "dmrg_electrons_opt", "dmrg_spins_list",
                     "dmrg_electrons_list"):
            cells.append((name, "davidson_m32k"))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dmrg", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args(argv)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all or args.dmrg:
        cells = all_cells() if args.all else [
            (n, "davidson_m32k") for n in ("dmrg_spins", "dmrg_electrons")
        ]
        failures = 0
        for arch, shape in cells:
            for mp in (False, True):
                tag = f"{arch} x {shape} [{'pod512' if mp else 'pod256'}]"
                try:
                    rec = run_cell(arch, shape, mp, out_dir, force=args.force)
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        print(f"OK   {tag}: dominant={r['dominant']} "
                              f"step>={r['step_s_lower_bound']:.4f}s "
                              f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                              f"(compile {rec['compile_s']:.0f}s)", flush=True)
                    else:
                        print(f"SKIP {tag}: {rec['reason']}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                   force=args.force)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
