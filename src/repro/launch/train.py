"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
        --steps 100 --checkpoint-every 20 --resume auto

Features demonstrated on CPU (and unchanged on a pod): sharded train step,
deterministic restorable data pipeline, async atomic checkpointing, resume
(elastic — restore re-shards onto the current mesh), straggler monitoring,
optional gradient compression with error feedback.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--checkpoint-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--compress", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args(argv)

    from repro import models
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh, mesh_context
    from repro.launch.sharding import batch_axes_for, tree_shardings
    from repro.launch import specs
    from repro.train.checkpoint import CheckpointManager
    from repro.train.data import SyntheticLM
    from repro.train.optim import OptConfig, init_opt_state, opt_state_axes
    from repro.train.straggler import StepMonitor

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    n_dev = len(jax.devices())
    mm = args.mesh_model
    mesh = make_mesh((n_dev // mm, mm), ("data", "model"))
    oc = OptConfig(lr=args.lr, warmup_steps=args.warmup,
                   total_steps=max(args.steps, 1))

    params, axes = models.init(cfg, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params)
    pshard = tree_shardings(params, axes, mesh)
    oshard = tree_shardings(opt, opt_state_axes(axes), mesh)
    params = {k: jax.device_put(v, pshard[k]) for k, v in params.items()}
    opt = {k: jax.device_put(v, oshard[k]) for k, v in opt.items()}

    data = SyntheticLM(cfg.vocab_size, args.seq_len, args.global_batch,
                       seed=args.seed)
    ckpt = CheckpointManager(f"{args.checkpoint_dir}/{cfg.name}", keep=3)
    start_step = 0
    if args.resume == "auto" and ckpt.latest_step() is not None:
        shardings = {f"p/{k}": s for k, s in pshard.items()}
        shardings.update({f"o/{k}": s for k, s in oshard.items()})
        step0, arrays, meta = ckpt.restore(shardings=shardings)
        params = {k[2:]: v for k, v in arrays.items() if k.startswith("p/")}
        opt = {k[2:]: v for k, v in arrays.items() if k.startswith("o/")}
        data.load_state_dict(meta["data"])
        start_step = step0
        print(f"resumed from step {step0}")

    step_fn = specs.make_train_step(cfg, oc, compress=args.compress)
    if args.compress:
        from repro.train.compress import init_error_state
        opt.update({f"err/{k}": v for k, v in init_error_state(params).items()})
        oshard = dict(oshard, **{f"err/{k}": pshard[k] for k in params})

    with mesh_context(mesh):
        jfn = jax.jit(step_fn, donate_argnums=(0, 1))
        mon = StepMonitor()
        extras = {}
        if cfg.family == "vlm":
            extras["patch_embeds"] = jnp.zeros(
                (args.global_batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            extras["enc_embeds"] = jnp.zeros(
                (args.global_batch, cfg.enc_seq_len, cfg.d_model), jnp.float32)

        losses = []
        for step in range(start_step, args.steps):
            batch = dict(next(data), **extras)
            mon.start()
            params, opt, metrics = jfn(params, opt, batch)
            loss = float(metrics["loss"])
            rep = mon.stop(step)
            losses.append(loss)
            if rep is not None:
                print(f"straggler@{step}: {rep.seconds:.3f}s vs ewma "
                      f"{rep.ewma:.3f}s (evict={rep.evict})")
            if args.log_every and step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if args.checkpoint_every and (step + 1) % args.checkpoint_every == 0:
                arrays = {f"p/{k}": v for k, v in params.items()}
                arrays.update({f"o/{k}": v for k, v in opt.items()})
                ckpt.save_async(step + 1, arrays,
                                meta={"data": data.state_dict(),
                                      "loss": loss})
        ckpt.wait()
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        return losses


if __name__ == "__main__":
    main()
