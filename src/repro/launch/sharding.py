"""Logical-axis -> mesh-axis resolution.

Each parameter/activation dim carries a logical axis name; ``RULES`` lists
candidate mesh axes per logical axis in priority order.  Assignment is greedy
per tensor with two constraints: a mesh axis is used at most once per tensor,
and the dim size must be divisible by the mesh axis size (falls through to
the next candidate, ultimately to replication).  This one mechanism expresses
TP ("model"), FSDP ("data"), EP (experts over "model"), DP over "pod", and
SP (cache sequence over "data" when batch can't shard, e.g. long_500k B=1).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidate = Union[str, Tuple[str, ...], None]

# priority-ordered candidates per logical axis
RULES: Dict[str, Sequence[AxisCandidate]] = {
    # weights
    "embed": ["data", None],          # FSDP shard of the "reduction" dim
    "embed2": [None],
    "heads": ["model", None],         # TP
    "kv_heads": ["model", None],
    "ff": ["model", None],
    "expert_ff": ["model", None],
    "expert": ["model", None],        # EP when divisible (64e), else fall back
    "expert_in": [None],
    "vocab": ["model", None],
    "rnn": ["model", None],
    "rnn2": [None],
    "lora": [None],
    "conv": [None],
    "head_dim": [None],
    "hidden": ["model", None],        # activation feature dim
    "layers": [None],                 # scan axis stays unsharded
    # activations / inputs
    "batch": [("pod", "data"), ("data",), None],
    "seq": [None],
    "cache_seq": ["data", "model", None],  # SP; "model" when batch takes "data"
    "cache_batch": [("pod", "data"), ("data",), None],
    "frames": [None],
    "patches": [None],
}


def _axis_size(mesh: Mesh, cand: AxisCandidate) -> int:
    if cand is None:
        return 1
    if isinstance(cand, tuple):
        return int(np.prod([mesh.shape[a] for a in cand]))
    return mesh.shape[cand]


def spec_for(shape: Tuple[int, ...], axes: Tuple[str, ...], mesh: Mesh) -> P:
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        chosen = None
        for cand in RULES.get(ax, [None]):
            if cand is None:
                break
            names = cand if isinstance(cand, tuple) else (cand,)
            if any(n not in mesh.shape for n in names):
                continue
            if any(n in used for n in names):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            chosen = cand
            used.update(names)
            break
        out.append(chosen)
    return P(*out)


def sharding_for(shape, axes, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(tuple(shape), tuple(axes), mesh))


def tree_shardings(shapes: Dict, axes: Dict, mesh: Mesh) -> Dict:
    """shapes: flat dict path -> ShapeDtypeStruct/array; axes: path -> tuple."""
    return {
        k: sharding_for(v.shape, axes[k], mesh) for k, v in shapes.items()
    }


def batch_axes_for(cfg, shape_kind: str) -> Dict[str, Tuple[str, ...]]:
    """Logical axes for each input-batch tensor of an arch."""
    ax: Dict[str, Tuple[str, ...]] = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
    }
    if cfg.family == "vlm":
        ax["patch_embeds"] = ("batch", "patches", "embed2")
    if cfg.family == "audio":
        ax["enc_embeds"] = ("batch", "frames", "embed2")
    return ax
