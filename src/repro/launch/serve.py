"""Batched serving driver: prefill-free cached decode over a request batch.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --smoke \
        --batch 4 --prompt-len 16 --gen-len 32

Feeds each request's prompt tokens through the jitted one-token decode step
(filling the KV/recurrent cache), then greedy-decodes ``gen-len`` tokens.
The same step function is what the decode_* dry-run cells lower at scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import models
    from repro.configs import get_config
    from repro.models.lm import padded_vocab

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params, _ = models.init(cfg, jax.random.PRNGKey(args.seed))
    cache_len = args.prompt_len + args.gen_len
    cache = models.init_cache(cfg, args.batch, cache_len)
    if cfg.family == "audio":
        from repro.models.whisper import whisper_prime_cache
        enc = jax.random.normal(jax.random.PRNGKey(1),
                                (args.batch, cfg.enc_seq_len, cfg.d_model),
                                jnp.float32)
        cache = whisper_prime_cache(cfg, params, cache, enc)

    step = jax.jit(
        lambda p, c, t, pos: models.decode_step(cfg, p, c, t, pos),
        donate_argnums=(1,),
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1),
        (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)

    # prefill by stepping the prompt through the cache
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t], jnp.int32(t))
    out = []
    for t in range(args.gen_len):
        nxt = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(nxt)
        logits, cache = step(params, cache, nxt,
                             jnp.int32(args.prompt_len + t))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = args.batch * (args.prompt_len + args.gen_len)
    gen = jnp.stack(out, axis=1)
    print(f"generated {gen.shape} tokens; {toks} steps in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(gen[0, :16]))
    return gen


if __name__ == "__main__":
    main()
