"""Roofline cost extraction from post-SPMD compiled HLO text.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts while-loop bodies
ONCE, which silently drops ~L x the flops of an L-layer scanned model.  This
module parses ``compiled.as_text()`` into the computation call graph, counts

  * flops            — dot ops: 2 * nelems(result) * prod(contracted dims)
  * hbm bytes        — operand + result bytes of top-level instructions
                       (fusion bodies excluded: their internals never hit HBM)
  * collective bytes — ring-model wire bytes per chip by collective type

per computation, and propagates totals through call edges with while-loop
trip-count multipliers (parsed from the loop condition's comparison constant).
All numbers are PER CHIP because the module is already SPMD-partitioned.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_TRIP_BC = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_TRIP = re.compile(r"constant\((\d+)\)")
_REPL_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPL_BRACES = re.compile(r"replica_groups=\{\{([^}]*)\}")

_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES}
    )
    calls: List[Tuple[str, str, int]] = dataclasses.field(default_factory=list)
    # (child, role, instr_id) where role in {"call", "body", "condition"};
    # body+condition of the same while share an instr_id
    trip_hint: int = 1  # for condition computations: max int constant seen
    trips: Dict[int, int] = dataclasses.field(default_factory=dict)
    # instr_id -> known_trip_count from the while's backend_config


def parse_computations(hlo: str) -> Tuple[Dict[str, CompCost], Optional[str]]:
    comps: Dict[str, CompCost] = {}
    entry = None
    cur: Optional[str] = None
    symtab: Dict[str, str] = {}
    instr_id = 0

    for raw in hlo.splitlines():
        m = _COMP_START.match(raw)
        if m and ("->" in raw):
            cur = m.group(1)
            comps[cur] = CompCost()
            symtab = {}
            if raw.startswith("ENTRY"):
                entry = cur
            # parameter shapes from the signature
            for pname, pshape in re.findall(r"%?([\w\.\-]+):\s*((?:\(|)[\w\[\],]*)",
                                            m.group(2)):
                symtab[pname] = pshape
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(raw)
        if not im:
            continue
        name, rshape, opcode, rest = im.groups()
        symtab[name] = rshape
        cc = comps[cur]
        instr_id += 1

        # call edges; while trips come from backend_config known_trip_count
        trip_bc = _TRIP_BC.search(raw)
        if trip_bc:
            cc.trips[instr_id] = int(trip_bc.group(1))
        for attr in _CALL_ATTR.finditer(raw):
            role = raw[attr.start():attr.start() + 4]
            role = {"body": "body", "cond": "condition"}.get(role, "call")
            cc.calls.append((attr.group(1), role, instr_id))

        # trip-count hint (int constants in this computation)
        if opcode == "constant":
            tm = _TRIP.search(raw)
            if tm:
                cc.trip_hint = max(cc.trip_hint, int(tm.group(1)))

        relems, rbytes = _shape_elems_bytes(rshape)

        # flops: dot = 2 * result_elems * contracted size
        if opcode == "dot":
            lhs_name = None
            om = _OPERAND.search(rest)
            if om:
                lhs_name = om.group(1)
            contracted = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", raw)
            if cm and lhs_name and lhs_name in symtab:
                lshape = _SHAPE.search(symtab[lhs_name])
                if lshape:
                    ldims = [int(x) for x in lshape.group(2).split(",") if x]
                    for ci in cm.group(1).split(","):
                        if ci:
                            contracted *= ldims[int(ci)]
            cc.flops += 2.0 * relems * contracted
        elif opcode in ("convolution",):
            cc.flops += 2.0 * relems  # lower bound; convs are negligible here

        # collectives (wire bytes, ring model)
        base_op = opcode[:-6] if opcode.endswith("-start") else opcode
        if base_op in COLLECTIVES:
            g = 1
            gm = _REPL_GROUPS.search(raw)
            if gm:
                g = int(gm.group(2))
            else:
                gb = _REPL_BRACES.search(raw)
                if gb:
                    g = len(gb.group(1).split(","))
            if base_op == "all-gather":
                wire = rbytes * (g - 1) / max(g, 1)
            elif base_op == "all-reduce":
                wire = 2.0 * rbytes * (g - 1) / max(g, 1)
            elif base_op == "reduce-scatter":
                wire = rbytes * (g - 1)
            elif base_op in ("all-to-all", "ragged-all-to-all"):
                wire = rbytes * (g - 1) / max(g, 1)
            else:  # collective-permute
                wire = float(rbytes)
            cc.coll[base_op] += wire

        # HBM traffic: result + operands, top-level non-bookkeeping ops
        if opcode not in _NO_TRAFFIC:
            obytes = 0
            # operands up to attribute section — conservative: names in rest
            for on in _OPERAND.findall(rest.split("),")[0]):
                if on in symtab:
                    _, ob = _shape_elems_bytes(symtab[on])
                    obytes += ob
            cc.bytes += rbytes + obytes

    return comps, entry


def total_costs(hlo: str) -> Dict:
    """Aggregate (flops, bytes, collectives) from ENTRY with while-trip
    multipliers.  Fusion-called computations contribute flops + collectives
    but not HBM bytes (their call site's operands/result already count)."""
    comps, entry = parse_computations(hlo)
    if entry is None:
        return dict(flops=0.0, bytes=0.0, coll={c: 0.0 for c in COLLECTIVES})

    # fusion bodies never touch HBM themselves — call-site operands count
    for c in comps.values():
        for child, role, _ in c.calls:
            if role == "call" and child in comps:
                comps[child].bytes = 0.0

    memo: Dict[str, Dict] = {}

    def walk(name: str, stack=()) -> Dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return dict(flops=0.0, bytes=0.0, coll={c: 0.0 for c in COLLECTIVES})
        c = comps[name]
        out = dict(flops=c.flops, bytes=c.bytes, coll=dict(c.coll))
        for child, role, iid in c.calls:
            if role == "condition":
                continue
            mult = 1
            if role == "body":
                # backend_config known_trip_count, else the condition's
                # comparison constant on the SAME while instruction
                mult = c.trips.get(iid, 0)
                if not mult:
                    for cd, r2, iid2 in c.calls:
                        if r2 == "condition" and iid2 == iid and cd in comps:
                            mult = max(mult, comps[cd].trip_hint)
                mult = max(mult, 1)
            sub = walk(child, stack + (name,))
            out["flops"] += mult * sub["flops"]
            out["bytes"] += mult * sub["bytes"]
            for k in out["coll"]:
                out["coll"][k] += mult * sub["coll"][k]
        memo[name] = out
        return out

    tot = walk(entry)
    tot["coll_total"] = sum(tot["coll"].values())
    return tot
