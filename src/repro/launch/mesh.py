"""Production meshes + TPU v5e hardware constants for the roofline.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because only
launch/dryrun.py requests 512 placeholder host devices.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes, devices=None):
    # axis_types landed after jax 0.4.x; Auto is the default there anyway
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes, devices=None):
    """Arbitrary (elastic) mesh with the same axis-type convention."""
    return _make_mesh(tuple(shape), tuple(axes), devices=devices)


def mesh_context(mesh):
    """Enter a mesh: jax.sharding.set_mesh where available (jax >= 0.5.x),
    else the legacy global-mesh context manager (``with mesh:``)."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


# TPU v5e, per chip (roofline constants from the assignment)
HW = dict(
    peak_flops_bf16=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw=50e9,              # B/s per link
    hbm_bytes=16 * 1024**3,   # 16 GiB
)
