"""Production meshes + TPU v5e hardware constants for the roofline.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because only
launch/dryrun.py requests 512 placeholder host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary (elastic) mesh with the same axis-type convention."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


# TPU v5e, per chip (roofline constants from the assignment)
HW = dict(
    peak_flops_bf16=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw=50e9,              # B/s per link
    hbm_bytes=16 * 1024**3,   # 16 GiB
)
