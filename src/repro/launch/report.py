"""Generate the EXPERIMENTS.md roofline tables from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]

Emits one markdown table per mesh with the three roofline terms, the
dominant bottleneck, peak memory, and MODEL_FLOPS/HLO_FLOPS usefulness ratio
per (arch x shape) cell.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load(dir_: str):
    recs = []
    for p in sorted(Path(dir_).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_row(r):
    if r["status"] != "ok":
        return None
    ro = r["roofline"]
    mem = r["memory"]
    ratio = r.get("useful_flops_ratio", 0.0)
    return (
        f"| {r['arch']} | {r['shape']} | "
        f"{ro['compute_s']:.3f} | {ro['memory_s']:.3f} | "
        f"{ro['collective_s']:.3f} | {ro['dominant']} | "
        f"{ro['step_s_lower_bound']:.3f} | "
        f"{mem['peak_bytes'] / 2**30:.1f} | "
        f"{(ratio if ratio else float('nan')):.2f} |"
    )


HEADER = (
    "| arch | shape | compute s | memory s | collective s | dominant | "
    "step>= s | peak GiB | useful |\n"
    "|---|---|---|---|---|---|---|---|---|"
)


def emit(dir_: str) -> str:
    recs = load(dir_)
    out = []
    for mesh in ("pod256", "pod512"):
        out.append(f"\n### Mesh {mesh} "
                   f"({'2x16x16 (pod,data,model)' if mesh == 'pod512' else '16x16 (data,model)'})\n")
        out.append(HEADER)
        skips = []
        for r in recs:
            if r["mesh"] != mesh:
                continue
            if r["status"] == "skipped":
                skips.append(f"{r['arch']} x {r['shape']}: {r['reason']}")
                continue
            row = fmt_row(r)
            if row:
                out.append(row)
        if skips:
            out.append("\nSkipped (per assignment rules): " + "; ".join(sorted(set(skips))))
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    a = ap.parse_args()
    print(emit(a.dir))
