"""Cell builders for the multi-pod dry-run: (step fn, ShapeDtypeStruct inputs,
in/out shardings) for every (architecture x input shape), plus the paper's
own DMRG Davidson workload at production bond dimension.

Everything here is allocation-free: parameters, optimizer state, and caches
are jax.eval_shape skeletons; only the dry-run lowers/compiles them.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import models
from ..configs import SHAPES, get_config
from ..train.optim import OptConfig, adamw_update, init_opt_state, opt_state_axes
from .sharding import batch_axes_for, sharding_for, tree_shardings


def _dtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def eval_params(cfg) -> Tuple[Dict, Dict]:
    """(params as ShapeDtypeStructs, logical axes) without allocating."""
    axes: Dict = {}

    def f():
        p, a = models.init(cfg, jax.random.PRNGKey(0))
        axes.update(a)
        return p

    params = jax.eval_shape(f)
    return params, axes


def batch_specs(cfg, shape_name: str, *, with_labels: bool) -> Tuple[Dict, Dict]:
    info = SHAPES[shape_name]
    b, s = info["global_batch"], info["seq_len"]
    dt = _dtype(cfg)
    specs, axes = {}, {}
    s_text = s
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches
        specs["patch_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
    if cfg.family == "audio":
        specs["enc_embeds"] = jax.ShapeDtypeStruct((b, cfg.enc_seq_len, cfg.d_model), dt)
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    ba = batch_axes_for(cfg, shape_name)
    axes = {k: ba[k] for k in specs}
    return specs, axes


# ------------------------------------------------------------------- steps
# gradient-accumulation microbatches per (arch) for the train_4k shape:
# bounds activation memory for the biggest models (peak must fit 16 GiB HBM)
MICROBATCHES = {
    "qwen15_110b": 8,
    "pixtral_12b": 4,
    "llama3_8b": 2,
    "codeqwen15_7b": 2,
    "moonshot_v1_16b_a3b": 4,
    "qwen2_moe_a27b": 2,
    "rwkv6_3b": 4,
    "recurrentgemma_2b": 2,
}


def make_train_step(cfg, oc: OptConfig, n_micro: int = 1, grad_shardings=None,
                    compress: str | None = None):
    def constrain(tree):
        if grad_shardings is None:
            return tree
        return {k: jax.lax.with_sharding_constraint(v, grad_shardings[k])
                for k, v in tree.items()}

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(
                lambda p: models.loss_fn(cfg, p, batch)
            )(params)
        else:
            def reshape(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

            mbatch = {k: reshape(v) for k, v in batch.items()}
            gzero = constrain({k: jnp.zeros(v.shape, jnp.float32)
                               for k, v in params.items()})

            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(
                    lambda p: models.loss_fn(cfg, p, mb)
                )(params)
                gsum = constrain(
                    {k: gsum[k] + g[k].astype(jnp.float32) for k in gsum}
                )
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(micro, (gzero, 0.0), mbatch)
            grads = {k: v / n_micro for k, v in gsum.items()}
            loss = lsum / n_micro
        if compress:
            from ..train.compress import compressed_grads

            err = {k[4:]: v for k, v in opt_state.items()
                   if k.startswith("err/")}
            opt_state = {k: v for k, v in opt_state.items()
                         if not k.startswith("err/")}
            grads, new_err = compressed_grads(grads, err, compress)
        new_p, new_s, metrics = adamw_update(oc, params, grads, opt_state)
        if compress:
            new_s.update({f"err/{k}": v for k, v in new_err.items()})
        metrics["loss"] = loss
        return new_p, new_s, metrics

    return train_step


def make_prefill_step(cfg):
    def prefill(params, batch):
        logits = models.forward(cfg, params, batch)
        return logits[:, -1, : cfg.vocab_size]  # next-token logits

    return prefill


def make_decode_step(cfg):
    def decode(params, cache, token, pos):
        return models.decode_step(cfg, params, cache, token, pos)

    return decode


# -------------------------------------------------------------------- cells
def lm_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args tuple of SDS, in_shardings, out_shardings,
    donate_argnums) for one dry-run cell."""
    cfg = get_config(arch)
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    info = SHAPES[shape_name]
    kind = info["kind"]
    params, paxes = eval_params(cfg)
    pshard = tree_shardings(params, paxes, mesh)
    repl = NamedSharding(mesh, P())

    if kind == "train":
        oc = OptConfig()
        opt = jax.eval_shape(init_opt_state, params)
        oshard = tree_shardings(opt, opt_state_axes(paxes), mesh)
        bspec, baxes = batch_specs(cfg, shape_name, with_labels=True)
        bshard = tree_shardings(bspec, baxes, mesh)
        fn = make_train_step(cfg, oc, MICROBATCHES.get(arch, 1),
                             grad_shardings=pshard)
        metrics_shard = {"grad_norm": repl, "lr": repl, "loss": repl}
        return (
            fn,
            (params, opt, bspec),
            (pshard, oshard, bshard),
            (pshard, oshard, metrics_shard),
            (0, 1),
        )

    if kind == "prefill":
        bspec, baxes = batch_specs(cfg, shape_name, with_labels=False)
        bshard = tree_shardings(bspec, baxes, mesh)
        fn = make_prefill_step(cfg)
        b = info["global_batch"]
        out_shard = sharding_for((b, cfg.vocab_size), ("batch", "seq"), mesh)
        return fn, (params, bspec), (pshard, bshard), out_shard, ()

    # decode: one new token against a seq_len-deep cache
    b, s = info["global_batch"], info["seq_len"]
    if cfg.family == "audio":
        from ..models.whisper import decode_cache_axes
    else:
        from ..models.lm import decode_cache_axes
    cache = jax.eval_shape(lambda: models.init_cache(cfg, b, s))
    caxes = decode_cache_axes(cfg)
    cshard = tree_shardings(cache, caxes, mesh)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = make_decode_step(cfg)
    from ..models.lm import padded_vocab

    lshard = sharding_for((b, padded_vocab(cfg)), ("batch", "vocab"), mesh)
    return (
        fn,
        (params, cache, token, pos),
        (pshard, cshard, repl, repl),
        (lshard, cshard),
        (1,),
    )


# ---------------------------------------------------------------- DMRG cell
DMRG_CELLS = {
    # the paper's production workloads (Sec. V-VI): two-site Davidson matvec
    # at large bond dimension, sparse-dense algorithm (dense distributed
    # tensors, single contraction call).  *_opt variants are the beyond-paper
    # hillclimbed versions (EXPERIMENTS.md §Perf): bf16 storage with f32 MXU
    # accumulation for the env tensors and the m^2*k*d^2 intermediates.
    "dmrg_spins": dict(m=32768, d=2, k=30, dtype="float32"),
    "dmrg_electrons": dict(m=16384, d=4, k=26, dtype="float32"),
    "dmrg_spins_opt": dict(m=32768, d=2, k=30, dtype="bfloat16"),
    "dmrg_electrons_opt": dict(m=16384, d=4, k=26, dtype="bfloat16"),
}


def dmrg_davidson_fn(m: int, d: int, k: int, store_dtype=jnp.float32):
    """One Davidson iteration body (paper Alg. 1 step): y = K x via the
    environment contraction of Fig. 1d, Rayleigh quotient, residual norm.
    Tensors are dense (sparse-dense algorithm) and sharded over the FULL
    mesh — the paper's core parallelization decision.  All contractions
    accumulate in f32; intermediates are stored in ``store_dtype``."""

    def ein(spec, a, b):
        r = jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
        return r.astype(store_dtype)

    def step(A, Wj, Wj1, B, x):
        t = ein("ikl,lstr->ikstr", A, x)               # m^3 k d^2
        t = ein("ikstr,kcsn->ictrn", t, Wj)            # m^2 k^2 d^3
        t = ein("ictrn,nftg->icfrg", t, Wj1)
        y = jnp.einsum("icfrg,jgr->icfj", t, B,
                       preferred_element_type=jnp.float32)  # m^3 k d^2
        xf = x.astype(jnp.float32)
        lam = jnp.sum(xf * y)                          # <x|K|x> (x normalized)
        resid = y - lam * xf
        rnorm = jnp.sqrt(jnp.sum(resid * resid))
        xnew = (resid / (rnorm + 1e-30)).astype(x.dtype)
        return lam, rnorm, xnew

    return step


def dmrg_cell(name: str, mesh):
    p = DMRG_CELLS[name]
    m, d, k = p["m"], p["d"], p["k"]
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[p["dtype"]]
    A = jax.ShapeDtypeStruct((m, k, m), dt)
    W = jax.ShapeDtypeStruct((k, d, d, k), dt)
    B = jax.ShapeDtypeStruct((m, k, m), dt)
    x = jax.ShapeDtypeStruct((m, d, d, m), dt)
    sh_env = NamedSharding(mesh, P(_data_axes(mesh), None, "model"))
    sh_w = NamedSharding(mesh, P())
    sh_x = NamedSharding(mesh, P(_data_axes(mesh), None, None, "model"))
    repl = NamedSharding(mesh, P())
    fn = dmrg_davidson_fn(m, d, k, store_dtype=dt)
    return (
        fn,
        (A, W, W, B, x),
        (sh_env, sh_w, sh_w, sh_env, sh_x),
        (repl, repl, sh_x),
        (),
    )


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else "data"


# ------------------------------------------------- DMRG list-algorithm cell
def empirical_block_dims(m: int, q: float, r: float, pad: int = 16):
    """The paper's fitted block model: b_l = floor((m/q) r^l) (Table II).

    ``pad`` rounds each block up to a multiple of the mesh-axis size so every
    block 2-D-shards over the full mesh (§Perf iteration: unpadded, the
    4915-dim block replicates — 2.9 GiB/chip; Cyclops handles arbitrary dims
    with cyclic layouts, the TPU adaptation pads instead, ~+6% flops)."""
    dims, b = [], m / q
    while int(b) >= 1 and sum(dims) < m:
        dims.append(max(pad, ((int(b) + pad - 1) // pad) * pad))
        b *= r
    return dims


def dmrg_list_cell(name: str, mesh):
    """The paper's *list* algorithm at production bond dimension: every
    quantum-number block is its own distributed dense tensor (sharded over
    the FULL mesh when its dims divide it — small tail blocks replicate,
    exactly the heterogeneity the paper highlights in Fig. 2a), and the
    Davidson matvec unrolls into one XLA program of per-block-pair GEMMs
    (the O(N_b) BSP supersteps collapse into overlapped compute).

    Block structure: one U(1) charge; bond sectors l = 0..N_b-1 with dims
    b_l from the paper's empirical model and charges q_l = l; physical
    charges +-1, so x blocks couple |q_l - q_r| <= 2 (banded, like the real
    MPS) and env blocks are charge-diagonal.
    """
    base = DMRG_CELLS[name.replace("_list", "")]
    m, d, k = base["m"], base["d"], base["k"]
    qq, rr = (4, 0.6) if "spins" in name else (10, 0.65)
    dims = empirical_block_dims(m, qq, rr)
    nb = len(dims)
    f32 = jnp.float32

    def shard2(d0: int, d1: int):
        """2-D shard a block when divisible; replicate the small tail."""
        da = _data_axes(mesh)
        dsz = int(np.prod([mesh.shape[a] for a in (da if isinstance(da, tuple) else (da,))]))
        p0 = da if d0 % dsz == 0 else None
        p1 = "model" if d1 % mesh.shape["model"] == 0 else None
        return p0, p1

    # ---- block lists (ShapeDtypeStructs) + shardings
    A_blocks, A_sh = [], []      # env: (q, q): [b_q, k, b_q]
    for i in range(nb):
        A_blocks.append(jax.ShapeDtypeStruct((dims[i], k, dims[i]), f32))
        p0, p1 = shard2(dims[i], dims[i])
        A_sh.append(NamedSharding(mesh, P(p0, None, p1)))
    # theta blocks (l, s1, s2, r): r-sector = l-sector + c(s1) + c(s2),
    # phys charges c(0)=+1, c(1)=-1 -> banded structure like the real MPS
    x_blocks, x_sh, x_keys = [], [], []
    for i in range(nb):
        for s1 in (0, 1):
            for s2 in (0, 1):
                j = i + (1 if s1 == 0 else -1) + (1 if s2 == 0 else -1)
                if 0 <= j < nb:
                    x_blocks.append(
                        jax.ShapeDtypeStruct((dims[i], 1, 1, dims[j]), f32))
                    p0, p1 = shard2(dims[i], dims[j])
                    x_sh.append(NamedSharding(mesh, P(p0, None, None, p1)))
                    x_keys.append((i, s1, s2, j))
    # sector-diagonal MPO block (trivial MPO-bond charge): [k, 1, 1, k]
    W = jax.ShapeDtypeStruct((k, 1, 1, k), f32)

    def list_matvec(A_list, Wj, Wj1, B_list, xs):
        """y = K x, list algorithm: enumerate compatible block 4-tuples."""
        ys = []
        for (i, s1, s2, j), xb in zip(x_keys, xs):
            t = jnp.einsum("ikl,lstr->ikstr", A_list[i], xb)
            t = jnp.einsum("ikstr,kcsn->ictrn", t, Wj)
            t = jnp.einsum("ictrn,nftg->icfrg", t, Wj1)
            y = jnp.einsum("icfrg,jgr->icfj", t, B_list[j])
            ys.append(y)
        lam = sum(jnp.sum(xb * yb) for xb, yb in zip(xs, ys))
        rn = jnp.sqrt(sum(jnp.sum((yb - lam * xb) ** 2)
                          for xb, yb in zip(xs, ys)))
        xnew = tuple((yb - lam * xb) / (rn + 1e-30) for xb, yb in zip(xs, ys))
        return lam, rn, xnew

    repl = NamedSharding(mesh, P())
    return (
        list_matvec,
        (tuple(A_blocks), W, W, tuple(A_blocks), tuple(x_blocks)),
        (tuple(A_sh), repl, repl, tuple(A_sh), tuple(x_sh)),
        (repl, repl, tuple(x_sh)),
        (),
    )
