"""Pure-jnp oracle for causal flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v):
    """q,k,v: [BH, S, D]; causal softmax attention in float32."""
    bh, s, d = q.shape
    logits = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / np.sqrt(d)
    mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    logits = jnp.where(mask[None], logits, -2.0**30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
