"""Pallas TPU kernel: causal flash attention (prefill hot-spot).

Online-softmax tiling (Dao et al., adapted to TPU memory hierarchy): the
grid is (batch*heads, S/bq, S/bk) with the key dim innermost; a float32
VMEM accumulator carries (m, l, acc) across key blocks, so the [S, S]
score matrix never leaves VMEM and HBM traffic is O(S*D) per head.  Fully
masked key blocks (block start beyond the causal frontier) are skipped via
pl.when — the TPU analogue of flash attention's triangular block pruning;
with bq == bk this halves the work vs. dense scoring.

Block shapes are (bq x d) / (bk x d) with d the head dim (128-lane aligned
for the MXU when d in {64,128,256}; the ops.py wrapper pads d otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, scale: float, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal frontier: key block strictly after the query block -> no work
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                   # [bq, bk]
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_ref[...]                         # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)             # [bq, 1]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(q, k, v, *, bq: int = 256, bk: int = 256,
                    scale: float | None = None, interpret: bool = False):
    """q,k,v: [BH, S, D] (heads pre-broadcast/flattened); causal.
    Returns [BH, S, D].  ``scale`` defaults to 1/sqrt(D) — pass the
    pre-padding head dim's scale when D was padded for lane alignment."""
    bh, s, d = q.shape
    bq, bk = min(bq, s), min(bk, s)
    assert s % bq == 0 and s % bk == 0
    nq, nk = s // bq, s // bk
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    grid = (bh, nq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
