"""Jit'd wrapper: [B,S,H,D] GQA layout -> flash kernel layout, with padding.

On TPU this is the production prefill path; on CPU (this container) it runs
in interpret mode for validation only — the jnp chunked attention in
models/attention.py is the lowering used by the dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _kernel
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret", "use_kernel"))
def flash_attention_bshd(q, k, v, *, bq: int = 256, bk: int = 256,
                         interpret: bool = False, use_kernel: bool = True):
    """q: [B,S,H,D]; k,v: [B,S,Hkv,D] (broadcast to H); causal; -> [B,S,H,D]."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        rep = h // hkv
        k = jnp.broadcast_to(k[:, :, :, None], (b, s, hkv, rep, d)).reshape(b, s, h, d)
        v = jnp.broadcast_to(v[:, :, :, None], (b, s, hkv, rep, d)).reshape(b, s, h, d)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    if use_kernel:
        dp = ((d + 127) // 128) * 128  # lane alignment
        if dp != d:
            pad = ((0, 0), (0, 0), (0, dp - d))
            qt, kt, vt = jnp.pad(qt, pad), jnp.pad(kt, pad), jnp.pad(vt, pad)
        o = _kernel(qt, kt, vt, bq=bq, bk=bk, scale=1.0 / (d ** 0.5),
                    interpret=interpret)[:, :, :d]
    else:
        o = flash_attention_ref(qt, kt, vt)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
