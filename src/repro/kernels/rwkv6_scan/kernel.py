"""Pallas TPU kernel: RWKV6 chunked linear-attention scan.

Implements the same overflow-free chunked algorithm as models/rwkv6.py
(cumulative log-decay, pairwise exponents <= 0), with the cross-chunk state
S [N, N] held in a float32 VMEM scratch across the sequential chunk grid
dim — state never round-trips to HBM within a head's scan.

Grid: (B*H, T/C); chunk dim innermost and sequential.  Per step, VMEM holds
r,k,v,logw chunk tiles [C, N], the [C, C] pairwise decay matrix per channel
loop... no — the pairwise term is computed as einsum over N inside VMEM:
for head dims N<=128 and chunks C<=64 everything fits comfortably
(C*C*N*4B = 1 MiB at C=64, N=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)      # [C, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)      # [1, N] bonus

    L = jnp.cumsum(lw, axis=0)            # inclusive
    Lprev = L - lw                        # exclusive
    s = s_ref[...]

    # carry-in from previous chunks
    carry = jax.lax.dot_general(
        r * jnp.exp(Lprev), s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # [C, N_v]

    # intra-chunk pairwise: A[t,i,n] = exp(Lprev[t,n] - L[i,n]), i < t
    expo = Lprev[:, None, :] - L[None, :, :]          # [C, C, N]
    A = jnp.exp(jnp.clip(expo, -60.0, 0.0))
    scores = jnp.einsum("tn,in,tin->ti", r, k, A)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    scores = jnp.where(mask, scores, 0.0)
    intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    bonus = jnp.sum(r * k * u, axis=1, keepdims=True) * v
    o_ref[0] = (carry + intra + bonus).astype(o_ref.dtype)

    # state update: S' = diag(exp(L_C)) S + sum_i exp(L_C - L_i) k_i (x) v_i
    Lc = L[-1:, :]                        # [1, N]
    kdec = k * jnp.exp(Lc - L)            # [C, N]
    s_ref[...] = s * jnp.exp(Lc)[0][:, None] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,logw: [BH, T, N]; u: [BH, N].  Returns wkv output [BH, T, N]."""
    bh, t, n = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    nc = t // chunk
    u2 = u[:, None, :]  # [BH, 1, N]

    grid = (bh, nc)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, n), r.dtype),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u2)
