"""Jit'd wrapper for the RWKV6 scan kernel ([B,T,H,N] model layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rwkv6_scan as _kernel
from .ref import rwkv6_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def rwkv6_wkv(r, k, v, logw, u, *, chunk: int = 32, interpret: bool = False,
              use_kernel: bool = True):
    """r,k,v,logw: [B,T,H,N]; u: [H,N] -> [B,T,H,N] wkv output."""
    b, t, h, n = r.shape

    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, n)

    rb, kb, vb, lb = to_bh(r), to_bh(k), to_bh(v), to_bh(logw)
    ub = jnp.tile(u, (b, 1))
    pad = (-t) % chunk
    if pad:
        rb, kb, vb = (jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
                      for a in (rb, kb, vb))
        lb = jnp.pad(lb, ((0, 0), (0, pad), (0, 0)))
    if use_kernel:
        o = _kernel(rb, kb, vb, lb, ub, chunk=chunk, interpret=interpret)
    else:
        o = rwkv6_scan_ref(rb, kb, vb, lb, ub)
    o = o[:, :t]
    return o.reshape(b, h, t, n).transpose(0, 2, 1, 3)
