"""Pure-jnp oracle for the RWKV6 scan kernel: naive O(T) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u):
    """r,k,v,logw: [BH,T,N]; u: [BH,N] -> out [BH,T,N].

    out_t = r_t . (S_t + u * k_t^T v_t);  S_{t+1} = diag(w_t) S_t + k_t^T v_t
    """
    bh, t, n = r.shape

    def step(s, i):
        kv = jnp.einsum("bn,bm->bnm", k[:, i], v[:, i])
        o = jnp.einsum("bn,bnm->bm", r[:, i], s + u[:, :, None] * kv)
        s = s * jnp.exp(logw[:, i])[:, :, None] + kv
        return s, o

    _, outs = jax.lax.scan(step, jnp.zeros((bh, n, n), jnp.float32),
                           jnp.arange(t))
    return jnp.moveaxis(outs, 0, 1)
