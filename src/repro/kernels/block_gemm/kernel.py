"""Pallas TPU kernel: batched block-sparse GEMM with scalar-prefetched routing.

This is the TPU-native adaptation of the paper's *sparse-sparse* contraction
algorithm (Sec. IV-A).  Cyclops contracts one distributed element-sparse
tensor pair per Davidson step; the TPU analogue keeps the sparsity at block
(tile) granularity: a static table of (lhs block, rhs block) -> output block
pairs, executed as ONE kernel launch (the paper's O(1) BSP supersteps), with
the MXU running dense 128-aligned tiles inside each quantum-number block.

Layout:
  lhs      [P, BM, BK]   packed/padded LHS block per pair
  rhs      [P, BK, BN]   packed/padded RHS block per pair
  out_idx  [P] int32     output block id per pair, MUST be sorted ascending,
                         and every o in [0, num_out) must appear at least once
                         (pack so each output block has >= 1 contributing pair)
  out      [O, BM, BN]   accumulated output blocks

Grid is (BM/bm, BN/bn, P, BK/bk) — pairs sweep contiguously for a fixed
output-tile position with k innermost, so consecutive pairs hitting the same
output block accumulate in a float32 VMEM scratch without round-tripping to
HBM.  The output BlockSpec index_map reads the scalar-prefetched ``out_idx``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(out_idx_ref, lhs_ref, rhs_ref, out_ref, acc_ref, *, nk: int):
    p = pl.program_id(2)
    k = pl.program_id(3)
    num_p = pl.num_programs(2)

    # first visit of this output tile by this group of pairs
    prev = out_idx_ref[jnp.maximum(p - 1, 0)]
    new_group = jnp.logical_or(p == 0, out_idx_ref[p] != prev)

    @pl.when(jnp.logical_and(new_group, k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        lhs_ref[0], rhs_ref[0], preferred_element_type=acc_ref.dtype
    )

    # flush when this is the last k-step of the last pair of the group
    nxt = out_idx_ref[jnp.minimum(p + 1, out_idx_ref.shape[0] - 1)]
    last_of_group = jnp.logical_or(p == num_p - 1, out_idx_ref[p] != nxt)

    @pl.when(jnp.logical_and(last_of_group, k == nk - 1))
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def block_sparse_matmul(
    lhs: jax.Array,
    rhs: jax.Array,
    out_idx: jax.Array,
    num_out: int,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """out[o] = sum_{p: out_idx[p]==o} lhs[p] @ rhs[p] via one pallas_call."""
    P, BM, BK = lhs.shape
    _, BK2, BN = rhs.shape
    assert BK == BK2 and out_idx.shape == (P,)
    bm, bn, bk = min(bm, BM), min(bn, BN), min(bk, BK)
    assert BM % bm == 0 and BN % bn == 0 and BK % bk == 0
    nm, nn, nk = BM // bm, BN // bn, BK // bk
    out_dtype = out_dtype or lhs.dtype
    # accumulate in f32 on the MXU; promote to f64 only for float64 inputs
    # (CPU interpret-mode validation — real TPUs have no f64)
    acc_dtype = jnp.float64 if lhs.dtype == jnp.float64 else jnp.float32

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, P, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda m, n, p, k, idx: (p, m, k)),
            pl.BlockSpec((1, bk, bn), lambda m, n, p, k, idx: (p, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda m, n, p, k, idx: (idx[p], m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_out, BM, BN), out_dtype),
        interpret=interpret,
    )(out_idx, lhs, rhs)
