"""Jit'd public wrapper for the block-sparse GEMM kernel.

Handles pair sorting, MXU-tile padding, and the interpret-mode fallback used
for CPU validation (this container has no TPU; ``interpret=True`` executes the
kernel body in Python, per-kernel tests assert allclose vs ``ref.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import block_sparse_matmul as _kernel_call
from .ref import block_sparse_matmul_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("num_out", "bm", "bn", "bk", "interpret")
)
def _kernel_covered(
    lhs: jax.Array,
    rhs: jax.Array,
    out_idx: jax.Array,
    num_out: int,
    *,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    """Pallas path; every output id in [0, num_out) must appear in out_idx."""
    P, BM, BK = lhs.shape
    _, _, BN = rhs.shape

    def _pad_dim(d: int, tile: int, align: int) -> int:
        p = _round_up(d, align)  # sublane/lane alignment
        return _round_up(p, tile) if p > tile else p  # tile divisibility

    pm = _pad_dim(BM, bm, 8)
    pk = _pad_dim(BK, bk, 128)
    pn = _pad_dim(BN, bn, 128)
    lhs_p = jnp.pad(lhs, ((0, 0), (0, pm - BM), (0, pk - BK)))
    rhs_p = jnp.pad(rhs, ((0, 0), (0, pk - BK), (0, pn - BN)))
    out = _kernel_call(
        lhs_p,
        rhs_p,
        out_idx.astype(jnp.int32),
        num_out,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=interpret,
    )
    return out[:, :BM, :BN]


_ref_jit = jax.jit(block_sparse_matmul_ref, static_argnames=("num_out",))


def block_sparse_matmul(
    lhs: jax.Array,
    rhs: jax.Array,
    out_idx: jax.Array,
    num_out: int,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Batched block-sparse GEMM: out[o] = sum_{p:out_idx[p]=o} lhs[p]@rhs[p].

    ``lhs``: [P, BM, BK]; ``rhs``: [P, BK, BN]; ``out_idx``: [P] int32 sorted.
    Pads BM/BK/BN up to multiples of the tile sizes (MXU alignment), runs the
    Pallas kernel, and slices the padding back off.

    Output blocks with no contributing pair are zero-filled: the ref path's
    ``segment_sum`` does this natively, and the Pallas kernel — which
    requires full output coverage — is handled by compacting to the covered
    ids and scattering into zeros.  Coverage is checked when ``out_idx`` is
    host-resident (numpy); plan-built device index tables always cover their
    outputs by construction and skip the check.
    """
    if not use_kernel:
        return _ref_jit(lhs, rhs, out_idx, num_out)
    kw = dict(bm=bm, bn=bn, bk=bk, interpret=interpret)
    if isinstance(out_idx, np.ndarray):
        covered = np.unique(out_idx)
        if covered.size < num_out:
            remap = np.zeros(num_out, np.int32)
            remap[covered] = np.arange(covered.size, dtype=np.int32)
            compact = _kernel_covered(
                lhs, rhs, remap[out_idx], int(covered.size), **kw
            )
            _, BM, _ = lhs.shape
            _, _, BN = rhs.shape
            zeros = jnp.zeros((num_out, BM, BN), compact.dtype)
            return zeros.at[covered].set(compact)
    return _kernel_covered(lhs, rhs, out_idx, num_out, **kw)


def pack_pairs(pairs, num_out):
    """Sort (lhs_i, rhs_i, out_i) triples by out block id; return index arrays.

    Output ids must lie in ``[0, num_out)`` (raises ``ValueError`` otherwise)
    but need not cover it: output blocks with zero contributing pairs are
    legal and come back zero-filled from ``block_sparse_matmul`` — the ref
    path's ``segment_sum`` zero-fills missing segments natively, and the
    Pallas path compacts to the covered ids and scatters into zeros.  That
    coverage check needs a host-resident (numpy) ``out_idx``, which is what
    this function returns; device-resident ids passed to the Pallas path
    are assumed to cover every output (see ``block_sparse_matmul``).
    """
    if not len(pairs):
        raise ValueError("pack_pairs: empty pair list")
    pairs = sorted(pairs, key=lambda t: t[2])
    li = np.array([p[0] for p in pairs], np.int32)
    ri = np.array([p[1] for p in pairs], np.int32)
    oi = np.array([p[2] for p in pairs], np.int32)
    if oi[0] < 0 or oi[-1] >= num_out:
        raise ValueError(
            f"pack_pairs: output ids must lie in [0, {num_out}), "
            f"got range [{oi[0]}, {oi[-1]}]"
        )
    return li, ri, oi
