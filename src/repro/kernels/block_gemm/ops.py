"""Jit'd public wrapper for the block-sparse GEMM kernel.

Handles pair sorting, MXU-tile padding, and the interpret-mode fallback used
for CPU validation (this container has no TPU; ``interpret=True`` executes the
kernel body in Python, per-kernel tests assert allclose vs ``ref.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import block_sparse_matmul as _kernel_call
from .ref import block_sparse_matmul_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("num_out", "bm", "bn", "bk", "interpret", "use_kernel")
)
def block_sparse_matmul(
    lhs: jax.Array,
    rhs: jax.Array,
    out_idx: jax.Array,
    num_out: int,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jax.Array:
    """Batched block-sparse GEMM: out[o] = sum_{p:out_idx[p]=o} lhs[p]@rhs[p].

    ``lhs``: [P, BM, BK]; ``rhs``: [P, BK, BN]; ``out_idx``: [P] int32 sorted.
    Pads BM/BK/BN up to multiples of the tile sizes (MXU alignment), runs the
    Pallas kernel, and slices the padding back off.
    """
    if not use_kernel:
        return block_sparse_matmul_ref(lhs, rhs, out_idx, num_out)
    P, BM, BK = lhs.shape
    _, _, BN = rhs.shape

    def _pad_dim(d: int, tile: int, align: int) -> int:
        p = _round_up(d, align)  # sublane/lane alignment
        return _round_up(p, tile) if p > tile else p  # tile divisibility

    pm = _pad_dim(BM, bm, 8)
    pk = _pad_dim(BK, bk, 128)
    pn = _pad_dim(BN, bn, 128)
    lhs_p = jnp.pad(lhs, ((0, 0), (0, pm - BM), (0, pk - BK)))
    rhs_p = jnp.pad(rhs, ((0, 0), (0, pk - BK), (0, pn - BN)))
    out = _kernel_call(
        lhs_p,
        rhs_p,
        out_idx.astype(jnp.int32),
        num_out,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=interpret,
    )
    return out[:, :BM, :BN]


def pack_pairs(pairs, num_out):
    """Sort (lhs_i, rhs_i, out_i) triples by out block id; return index arrays."""
    pairs = sorted(pairs, key=lambda t: t[2])
    li = np.array([p[0] for p in pairs], np.int32)
    ri = np.array([p[1] for p in pairs], np.int32)
    oi = np.array([p[2] for p in pairs], np.int32)
    assert len(set(oi.tolist())) == num_out, "every output block needs >=1 pair"
    return li, ri, oi
