"""Pure-jnp oracle for the batched block-sparse GEMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_sparse_matmul_ref(
    lhs: jax.Array, rhs: jax.Array, out_idx: jax.Array, num_out: int, out_dtype=None
) -> jax.Array:
    """out[o] = sum_{p: out_idx[p]==o} lhs[p] @ rhs[p] (segment-sum oracle)."""
    out_dtype = out_dtype or lhs.dtype
    acc = jnp.float64 if lhs.dtype == jnp.float64 else jnp.float32
    prod = jnp.einsum(
        "pmk,pkn->pmn", lhs.astype(acc), rhs.astype(acc)
    )
    out = jax.ops.segment_sum(prod, out_idx, num_segments=num_out)
    return out.astype(out_dtype)
