"""DMRG end-to-end correctness vs exact diagonalization (both paper systems,
all three contraction algorithms)."""
import numpy as np
import pytest

from repro.core import run_dmrg
from repro.core.ed import build_dense_hamiltonian, ground_energy
from repro.core.env import expectation
from repro.core.models import heisenberg_j1j2_terms, triangular_hubbard_terms
from repro.core.mpo import build_mpo, compress_mpo, mpo_bond_dims
from repro.core.mps import neel_states, product_state_mps, total_charge
from repro.core.opterm import fermi_hop, term
from repro.core.siteops import electron_space, spin_half_space


class TestED:
    def test_heisenberg_dimer(self):
        sp = spin_half_space()
        terms = [
            term(0.5, ("S+", 0), ("S-", 1)),
            term(0.5, ("S-", 0), ("S+", 1)),
            term(1.0, ("Sz", 0), ("Sz", 1)),
        ]
        assert abs(ground_energy(sp, terms, 2) - (-0.75)) < 1e-12

    def test_hubbard_dimer_analytic(self):
        el = electron_space()
        t, U = 1.0, 8.0
        terms = (
            fermi_hop(-t, "adag_up", "a_up", 0, 1, "adagF_up", "Fa_up")
            + fermi_hop(-t, "adag_dn", "a_dn", 0, 1, "adagF_dn", "Fa_dn")
            + [term(U, ("nupdn", 0)), term(U, ("nupdn", 1))]
        )
        exact = (U - np.sqrt(U * U + 16 * t * t)) / 2
        assert abs(ground_energy(el, terms, 2, charge=(2, 0)) - exact) < 1e-12


class TestMPO:
    def test_expectation_matches_ed(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        n = 6
        mpo = build_mpo(sp, terms, n)
        states = neel_states(sp, n)
        mps = product_state_mps(sp, states)
        e_mpo = float(expectation(mps.tensors, mpo))
        H = build_dense_hamiltonian(sp, terms, n)
        idx = int("".join(str(s) for s in states), 2)
        assert abs(e_mpo - H[idx, idx]) < 1e-12

    @pytest.mark.x64
    def test_compression_preserves_expectation(self):
        el = electron_space()
        terms = triangular_hubbard_terms(3, 2, 1.0, 8.5, cylinder=False)
        mpo = build_mpo(el, terms, 6)
        mpoc = compress_mpo(mpo, cutoff=1e-13)
        assert max(mpo_bond_dims(mpoc)) < max(mpo_bond_dims(mpo))
        mps = product_state_mps(el, neel_states(el, 6))
        e1 = float(expectation(mps.tensors, mpo))
        e2 = float(expectation(mps.tensors, mpoc))
        assert abs(e1 - e2) < 1e-9


@pytest.mark.x64
class TestDMRGvsED:
    def test_spins_2x3(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        e0 = ground_energy(sp, terms, 6, charge=(0,))
        res = run_dmrg(sp, terms, 6, bond_schedule=(8, 16), sweeps_per_bond=2,
                       davidson_iters=6)
        assert abs(res.energy - e0) < 1e-8

    def test_electrons_chain4(self):
        el = electron_space()
        terms = triangular_hubbard_terms(4, 1, 1.0, 8.5, cylinder=False)
        q = total_charge(el, neel_states(el, 4))
        e0 = ground_energy(el, terms, 4, charge=q)
        res = run_dmrg(el, terms, 4, bond_schedule=(8, 16), sweeps_per_bond=2,
                       davidson_iters=8)
        assert abs(res.energy - e0) < 1e-8

    @pytest.mark.parametrize("algo", ["dense", "csr_ref"])
    def test_algorithms_agree(self, algo):
        """sparse-dense and block-CSR sweeps land on the same ground state."""
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        e0 = ground_energy(sp, terms, 6, charge=(0,))
        res = run_dmrg(sp, terms, 6, bond_schedule=(8, 16), sweeps_per_bond=2,
                       davidson_iters=6, algo=algo)
        assert abs(res.energy - e0) < 1e-7

    def test_energy_monotone_nonincreasing(self):
        """Variational: sweep energies must not increase (paper's monotonicity)."""
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        res = run_dmrg(sp, terms, 6, bond_schedule=(4, 8, 16), sweeps_per_bond=1,
                       davidson_iters=4)
        es = res.energies
        assert all(es[i + 1] <= es[i] + 1e-9 for i in range(len(es) - 1))
