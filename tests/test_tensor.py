"""Block-sparse tensor substrate: charge conservation, algorithm equivalence,
SVD truncation invariants.  Property tests use hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.tensor import (
    BlockSparseTensor,
    IN,
    Index,
    OUT,
    contract,
    contract_block_csr,
    contract_dense,
    svd_split,
)
from repro.tensor.blocksparse import flip_flow


def rand_index(rng, nq=1, max_sectors=3, max_dim=4, flow=OUT):
    ns = rng.integers(1, max_sectors + 1)
    charges = rng.choice(np.arange(-2, 3), size=(8, nq), replace=True)
    charges = [tuple(int(c) for c in q) for q in charges]
    uniq = []
    for q in charges:
        if q not in uniq:
            uniq.append(q)
    uniq = uniq[:ns]
    return Index(tuple((q, int(rng.integers(1, max_dim + 1))) for q in uniq), flow)


def rand_pair(seed, nq=1):
    """Random contractible (A, B) pair sharing one contracted index."""
    rng = np.random.default_rng(seed)
    shared = rand_index(rng, nq=nq)
    ia = rand_index(rng, nq=nq)
    ib = rand_index(rng, nq=nq)
    A = BlockSparseTensor.random([ia, shared], key=jax.random.PRNGKey(seed))
    B = BlockSparseTensor.random([shared.dual(), ib], key=jax.random.PRNGKey(seed + 1))
    return A, B


class TestChargeConservation:
    @given(seed=st.integers(0, 200), nq=st.integers(1, 2))
    @settings(max_examples=25, deadline=None)
    def test_contract_conserves_charge(self, seed, nq):
        A, B = rand_pair(seed, nq)
        C = contract(A, B, axes=((1,), (0,)))
        C.check()

    @given(seed=st.integers(0, 200))
    @settings(max_examples=10, deadline=None)
    def test_flip_flow_invariant(self, seed):
        A, B = rand_pair(seed)
        C1 = contract(A, B, axes=((1,), (0,))).to_dense()
        A2, B2 = flip_flow(A, 1), flip_flow(B, 0)
        C2 = contract(A2, B2, axes=((1,), (0,))).to_dense()
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-12)


class TestAlgorithmEquivalence:
    """The paper's three contraction algorithms must agree exactly."""

    @given(seed=st.integers(0, 500), nq=st.integers(1, 2))
    @settings(max_examples=25, deadline=None)
    def test_list_vs_dense(self, seed, nq):
        A, B = rand_pair(seed, nq)
        C1 = contract(A, B, axes=((1,), (0,))).to_dense()
        C2 = contract_dense(A, B, axes=((1,), (0,))).to_dense()
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-12)

    @given(seed=st.integers(0, 500))
    @settings(max_examples=10, deadline=None)
    def test_list_vs_block_csr(self, seed):
        A, B = rand_pair(seed)
        C1 = contract(A, B, axes=((1,), (0,))).to_dense()
        C3 = contract_block_csr(A, B, axes=((1,), (0,)), interpret=True).to_dense()
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C3), atol=1e-10)

    @pytest.mark.x64
    def test_higher_order(self):
        rng = np.random.default_rng(7)
        i1, i2, i3 = (rand_index(rng) for _ in range(3))
        A = BlockSparseTensor.random([i1, i2, i3], key=jax.random.PRNGKey(0))
        B = BlockSparseTensor.random(
            [i2.dual(), i3.dual(), i1], key=jax.random.PRNGKey(1)
        )
        ax = ((1, 2), (0, 1))
        C1 = contract(A, B, axes=ax).to_dense()
        C2 = contract_dense(A, B, axes=ax).to_dense()
        C3 = contract_block_csr(A, B, axes=ax, interpret=True).to_dense()
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-12)
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C3), atol=1e-10)


@pytest.mark.x64
class TestSVD:
    def _theta(self, seed=3):
        for s in range(seed, seed + 50):  # ensure a non-empty block structure
            rng = np.random.default_rng(s)
            ixs = [rand_index(rng, flow=f) for f in (IN, OUT, OUT, OUT)]
            t = BlockSparseTensor.random(ixs, key=jax.random.PRNGKey(s))
            if t.num_blocks > 1:
                return t
        raise RuntimeError("no non-trivial theta found")

    def test_exact_roundtrip(self):
        theta = self._theta()
        U, V, _, err = svd_split(theta, 2, max_bond=10**6, cutoff=0.0)
        U.check(), V.check()
        rec = contract(U, V, axes=((2,), (0,)))
        np.testing.assert_allclose(
            np.asarray(rec.to_dense()), np.asarray(theta.to_dense()), atol=1e-12
        )
        assert err < 1e-24

    def test_isometry(self):
        """U must be left-orthogonal: U† U = I on the bond."""
        theta = self._theta()
        U, _, _, _ = svd_split(theta, 2, max_bond=10**6, cutoff=0.0, absorb="right")
        gram = contract(U.conj(), U, axes=((0, 1), (0, 1))).to_dense()
        np.testing.assert_allclose(
            np.asarray(gram), np.eye(gram.shape[0]), atol=1e-12
        )

    @given(max_bond=st.integers(1, 12))
    @settings(max_examples=8, deadline=None)
    def test_truncation_error_equals_discarded_weight(self, max_bond):
        theta = self._theta(11)
        U, V, _, err = svd_split(theta, 2, max_bond=max_bond, cutoff=0.0)
        rec = contract(U, V, axes=((2,), (0,)))
        actual = float(np.sum(np.abs(np.asarray(rec.to_dense() - theta.to_dense())) ** 2))
        np.testing.assert_allclose(actual, err, rtol=1e-8, atol=1e-12)


class TestPytree:
    def test_jit_through_blocksparse(self):
        A, B = rand_pair(0)

        @jax.jit
        def f(a, b):
            return contract(a, b, axes=((1,), (0,)))

        C1 = f(A, B).to_dense()
        C2 = contract(A, B, axes=((1,), (0,))).to_dense()
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=1e-12)
