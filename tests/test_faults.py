"""Fault injection, health guards, degradation ladder, checkpoint/resume,
and the serving layer's isolation/bisection/watchdog recovery (DESIGN.md 3.8).

The contract under test: an injected failure anywhere in the pipeline is
(a) detected at an existing host-sync point, (b) recovered on a documented
ladder whose bottom rung is the seed algorithms, and (c) invisible in the
final physics — recovered energies match a clean run to <1e-10 (the seed-
equality guarantee), and in a serving batch only the poisoned request
fails while its slot-mates return clean-run energies.
"""
import math
import os
import pickle

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import run_dmrg
from repro.core.checkpoint import CheckpointManager
from repro.core.models import heisenberg_chain_system
from repro.core.mpo import build_mpo, compress_mpo
from repro.core.mps import neel_states, product_state_mps
from repro.core.siteops import spin_half_space
from repro.core.sweep import DMRGEngine
from repro.dist import faults
from repro.dist.engine import CONTRACTION_LADDER, ContractionEngine
from repro.dist.faults import FaultInjected, FaultRegistry, NumericalHealthError
from repro.serve import DMRGService, ProblemSpec, StackedOps
from repro.serve.problems import build_problem


@pytest.fixture(autouse=True)
def _clean_registry():
    """No fault leaks between tests: every test starts and ends disarmed."""
    faults.registry.clear()
    yield
    faults.registry.clear()


N = 6  # chain length for the single-problem recovery tests


def _engine(algo="batched", **kw):
    space, terms = heisenberg_chain_system(N, h=0.3)
    mpo = compress_mpo(build_mpo(space, terms, N), cutoff=1e-13)
    mps = product_state_mps(space, neel_states(space, N))
    return DMRGEngine(mps, mpo, algo=algo, davidson_iters=4, **kw)


def _two_sweeps(eng, m=8):
    eng.sweep(max_bond=m)
    return eng.sweep(max_bond=m)


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_unknown_point_raises(self):
        reg = FaultRegistry()
        with pytest.raises(KeyError, match="unknown fault point"):
            reg.arm("decomp.typo_fail")

    def test_after_count_window(self):
        reg = FaultRegistry()
        f = reg.arm("decomp.svd_fail", after=2, count=2)
        hits = [reg.fire("decomp.svd_fail") is not None for _ in range(6)]
        assert hits == [False, False, True, True, False, False]
        assert f.seen == 6 and f.fired == 2

    def test_count_inf_fires_forever(self):
        reg = FaultRegistry()
        reg.arm("batch.gemm_nan", count=math.inf)
        assert all(reg.fire("batch.gemm_nan") is not None for _ in range(50))

    def test_inject_context_disarms(self):
        with faults.inject("env.exception") as f:
            assert faults.fire("env.exception") is not None
            assert f.fired == 1
        assert faults.fire("env.exception") is None

    def test_arm_from_env_grammar(self):
        reg = FaultRegistry()
        reg.arm_from_env(
            "decomp.svd_fail:count=inf:after=1, serve.slot_latency:value=0.25"
        )
        assert reg.fire("decomp.svd_fail") is None  # after=1 skips first
        assert reg.fire("decomp.svd_fail").count == math.inf
        assert reg.fire("serve.slot_latency").value == 0.25
        with pytest.raises(ValueError, match="bad REPRO_FAULTS knob"):
            reg.arm_from_env("decomp.svd_fail:boom=1")
        with pytest.raises(KeyError):
            reg.arm_from_env("no.such_point")

    def test_stats_reports_armed_and_fired(self):
        reg = FaultRegistry()
        reg.arm("sweep.kill")
        reg.fire("sweep.kill")
        s = reg.stats()
        assert s["armed"] == ["sweep.kill"]
        assert s["fired"] == {"sweep.kill": 1}


# ------------------------------------------------- guards + degradation ladder
class TestDegradationLadder:
    def test_ladder_ordering(self):
        """The documented ladder runs fastest-to-safest, ending at the seed,
        and a failed rung only ever retries rungs BELOW itself."""
        assert CONTRACTION_LADDER == ("spmd", "csr", "batched", "dense", "list")
        for i, rung in enumerate(CONTRACTION_LADDER):
            below = CONTRACTION_LADDER[CONTRACTION_LADDER.index(rung) + 1:]
            assert below == CONTRACTION_LADDER[i + 1:]

    def test_clean_run_zero_counters(self):
        eng = _engine(algo="batched", jit_matvec=True)
        stats = _two_sweeps(eng)
        st_ = eng.contract_fn.stats()
        assert not any(st_["retries"].values())
        assert not any(st_["degradations"].values())
        assert st_["decomp"]["retries"] == 0
        assert not any(st_["decomp"]["degradations"].values())
        assert stats.pair_retries == 0

    @pytest.mark.x64
    def test_decomp_svd_fail_recovers_equal(self):
        ref = _two_sweeps(_engine())
        eng = _engine()
        with faults.inject("decomp.svd_fail", count=1) as f:
            got = _two_sweeps(eng)
        assert f.fired == 1
        assert abs(got.energy - ref.energy) < 1e-10
        d = eng.contract_fn.stats()["decomp"]
        assert d["retries"] >= 1
        assert sum(d["degradations"].values()) >= 1

    @pytest.mark.x64
    def test_env_exception_falls_back_to_seed_equal(self):
        ref = _two_sweeps(_engine())
        eng = _engine()
        with faults.inject("env.exception", count=2) as f:
            got = _two_sweeps(eng)
        assert f.fired == 2
        assert abs(got.energy - ref.energy) < 1e-10
        st_ = eng.contract_fn.stats()
        assert st_["retries"].get("env", 0) >= 2
        assert st_["degradations"].get("env_seed", 0) >= 2

    @pytest.mark.x64
    def test_gemm_nan_pair_retries_on_seed_rung_equal(self):
        """A NaN-poisoned batched GEMM surfaces at the Davidson host sync as
        a NumericalHealthError; the pair re-runs on the seed rung and the
        final energy still matches a clean run."""
        ref = _two_sweeps(_engine(algo="batched", jit_matvec=False))
        eng = _engine(algo="batched", jit_matvec=False)
        with faults.inject("batch.gemm_nan", count=1) as f:
            got = _two_sweeps(eng)
        assert f.fired == 1
        assert abs(got.energy - ref.energy) < 1e-10
        assert got.pair_retries + eng.contract_fn.retries.get("pair", 0) >= 1
        assert eng.contract_fn.degradations.get("pair_seed", 0) >= 1

    def test_davidson_health_surfaced_in_sweep_stats(self):
        clean = _two_sweeps(_engine())  # per-sweep stats: 2 passes x (N-1)
        assert clean.davidson_solves == 2 * (N - 1)
        assert clean.davidson_iterations >= clean.davidson_solves
        eng = _engine()
        with faults.inject("davidson.no_converge", count=math.inf):
            forced = _two_sweeps(eng)
        assert forced.davidson_converged == 0
        assert forced.davidson_solves == clean.davidson_solves

    def test_health_error_carries_stage_and_mask(self):
        e = NumericalHealthError("bad", stage="svd",
                                 problems=np.array([False, True]))
        assert e.stage == "svd"
        assert list(e.problems) == [False, True]
        assert isinstance(e, RuntimeError)


# ------------------------------------------------------- checkpoint/resume
class TestCheckpoint:
    def _state(self, step):
        return {"step": step, "payload": list(range(step))}

    def test_roundtrip_and_prune(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), every=1, keep=2)
        for s in range(1, 6):
            cm.save(self._state(s))
        files = sorted(os.listdir(tmp_path))
        assert files == ["ckpt_00000004.pkl", "ckpt_00000005.pkl"]
        assert cm.load_latest()["step"] == 5

    def test_maybe_save_cadence(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), every=3, keep=10)
        saved = [cm.maybe_save(self._state(s)) for s in range(1, 7)]
        assert [bool(p) for p in saved] == [False, False, True,
                                            False, False, True]

    def test_truncated_newest_degrades_to_previous(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), every=1, keep=2)
        cm.save(self._state(1))
        cm.save(self._state(2))
        newest = os.path.join(tmp_path, "ckpt_00000002.pkl")
        with open(newest, "wb") as f:
            f.write(b"\x80\x04garbage")  # crash mid-write stand-in
        assert cm.load_latest()["step"] == 1

    def test_version_mismatch_skipped(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), every=1, keep=2)
        cm.save(self._state(1))
        bad = {"step": 2, "version": 999}
        with open(os.path.join(tmp_path, "ckpt_00000002.pkl"), "wb") as f:
            pickle.dump(bad, f)
        assert cm.load_latest()["step"] == 1

    @pytest.mark.x64
    def test_kill_mid_sweep_resume_equal(self, tmp_path):
        """Kill the run after the 4th site update of the schedule; a rerun
        with the same checkpoint dir resumes MID-SWEEP and its energies
        match the uninterrupted run to <1e-10 (bit-identical in practice)."""
        space, terms = heisenberg_chain_system(N, h=0.3)
        kw = dict(bond_schedule=(8, 12), sweeps_per_bond=1,
                  davidson_iters=4, algo="batched")
        ref = run_dmrg(space, terms, N, **kw)
        ckdir = str(tmp_path / "ck")
        with faults.inject("sweep.kill", after=3, count=1) as f:
            with pytest.raises(FaultInjected):
                run_dmrg(space, terms, N, checkpoint_dir=ckdir, **kw)
        assert f.fired == 1
        res = run_dmrg(space, terms, N, checkpoint_dir=ckdir, **kw)
        assert abs(res.energy - ref.energy) < 1e-10
        for a, b in zip(res.sweep_stats, ref.sweep_stats):
            assert abs(a.energy - b.energy) < 1e-10


# ------------------------------------------------------------ serving layer
SPECS = [
    ProblemSpec.make("heisenberg", 6, J=1.0 + 0.05 * i, max_bond=8,
                     sweeps_per_bond=1, davidson_iters=4)
    for i in range(4)
]


_OPS = None
_CLEAN = None


def _get_ops():
    """One StackedOps across the serving tests: compile the pipeline once.

    A lazy module global rather than a fixture because the hypothesis test
    below cannot take fixtures (the deterministic stub in
    ``_hypothesis_stub.py`` hides the wrapped signature from pytest)."""
    global _OPS
    if _OPS is None:
        _OPS = StackedOps()
    return _OPS


def _manual_service(ops, **kw):
    """Service with no worker thread: tests drive slots deterministically."""
    return DMRGService(max_batch=4, start=False, ops=ops, **kw)


def _drain_one_slot(svc):
    """What one worker iteration does: cut a slot, mark running, solve."""
    with svc._cv:
        slot = svc.scheduler.next_batch()
        assert slot is not None
        for rid in slot.rids:
            svc._requests[rid]["status"] = "running"
    svc._run_slot(slot)
    return slot


def _get_clean_energies():
    """Reference energies: each spec solved alone through the same ops."""
    global _CLEAN
    if _CLEAN is None:
        svc = _manual_service(_get_ops())
        out = {}
        for spec in SPECS:
            rid = svc.submit(spec)
            _drain_one_slot(svc)
            out[spec] = svc.result(rid, timeout=5.0)["energy"]
        svc.shutdown()
        _CLEAN = out
    return _CLEAN


class TestServeRecovery:
    @pytest.mark.x64
    @given(target=st.integers(0, 3))
    @settings(max_examples=3, deadline=None)
    def test_poisoned_request_isolated(self, target):
        """One NaN-poisoned request in a slot of 4 fails EXACTLY itself;
        the other three return energies matching their clean solo runs to
        <1e-10 (phantom-slot exactness: batch composition never changes
        per-problem numerics)."""
        clean_energies = _get_clean_energies()
        faults.registry.clear()  # hypothesis re-enters past the fixture
        svc = _manual_service(_get_ops(), max_retries=0)
        rids = [svc.submit(s) for s in SPECS]
        # count=inf + rid targeting: the poison follows the request through
        # every isolation retry, like persistently corrupt upstream input
        faults.registry.arm("serve.poison_request", count=math.inf,
                            problem=rids[target])
        _drain_one_slot(svc)
        faults.registry.clear()
        for i, (rid, spec) in enumerate(zip(rids, SPECS)):
            if i == target:
                with pytest.raises(RuntimeError, match="failed"):
                    svc.result(rid, timeout=5.0)
            else:
                rec = svc.result(rid, timeout=5.0)
                assert abs(rec["energy"] - clean_energies[spec]) < 1e-10
        st_ = svc.stats()
        assert st_["failed"] == 1 and st_["completed"] == 3
        svc.shutdown()

    @pytest.mark.x64
    def test_unmasked_failure_bisects(self):
        """A whole-slot failure with no mask (stand-in: LAPACK SVD dying)
        bisects; the halves rerun clean once the transient fault is gone.

        x64-marked not for tolerances but for a precondition: under f32 the
        MPO compression of the two J values yields different block
        structures, so the specs land in different batch groups and no
        multi-request slot (nothing to bisect) ever forms."""
        svc = _manual_service(_get_ops())
        rids = [svc.submit(s) for s in SPECS[:2]]
        with faults.inject("decomp.svd_fail", count=1) as f:
            _drain_one_slot(svc)
        assert f.fired == 1
        for rid in rids:
            assert svc.result(rid, timeout=5.0)["status"] == "done"
        st_ = svc.stats()
        assert st_["bisections"] == 1
        assert st_["failed"] == 0
        assert st_["davidson"]["solves"] > 0  # health surfaced in stats JSON
        svc.shutdown()

    def test_single_request_retry_budget_exhausts(self):
        svc = _manual_service(_get_ops(), max_retries=1)
        rid = svc.submit(SPECS[0])
        with faults.inject("decomp.svd_fail", count=math.inf):
            _drain_one_slot(svc)
        with pytest.raises(RuntimeError, match="failed"):
            svc.result(rid, timeout=5.0)
        st_ = svc.stats()
        assert st_["retries"] == 2  # initial charge + one budgeted re-run
        assert st_["failed"] == 1
        svc.shutdown()

    def test_worker_crash_restarts_and_recovers(self):
        svc = DMRGService(max_batch=4, ops=_get_ops(), batch_wait_s=0.01)
        faults.registry.arm("serve.worker_crash", count=1)
        rid = svc.submit(SPECS[0])
        rec = svc.result(rid, timeout=120.0)
        assert rec["status"] == "done"
        assert svc.stats()["worker_restarts"] == 1
        svc.shutdown()

    def test_cancel_pending_request(self):
        svc = _manual_service(_get_ops())
        r0 = svc.submit(SPECS[0])
        r1 = svc.submit(SPECS[1])
        assert svc.cancel(r0) is True
        assert svc.cancel(r0) is False  # already cancelled
        assert svc.poll(r0)["status"] == "cancelled"
        with pytest.raises(RuntimeError, match="cancelled"):
            svc.result(r0, timeout=1.0)
        _drain_one_slot(svc)  # r1 alone; r0 must not be solved
        assert svc.result(r1, timeout=5.0)["status"] == "done"
        st_ = svc.stats()
        assert st_["cancelled"] == 1 and st_["completed"] == 1
        svc.shutdown()

    def test_result_evicts_into_bounded_tombstones(self):
        """The delivered-result leak is fixed: result() evicts the live
        record; late poll() answers from a bounded tombstone map."""
        svc = _manual_service(_get_ops(), max_tombstones=2)
        rids = [svc.submit(s) for s in SPECS[:3]]
        while len(svc.scheduler):
            _drain_one_slot(svc)
        for rid in rids:
            svc.result(rid, timeout=5.0)
        assert svc._requests == {}  # nothing retained after delivery
        assert svc.poll(rids[-1])["status"] == "done"  # tombstone answers
        with pytest.raises(KeyError):  # oldest pushed out of the bound
            svc.poll(rids[0])
        svc.shutdown()

    def test_journal_recovery_reenqueues(self, tmp_path):
        ckdir = str(tmp_path)
        svc1 = _manual_service(_get_ops(), checkpoint_dir=ckdir)
        rids = [svc1.submit(s) for s in SPECS[:2]]
        assert os.path.exists(os.path.join(ckdir, "serve_journal.json"))
        # no shutdown: simulate the process dying with work undelivered
        svc2 = _manual_service(_get_ops(), checkpoint_dir=ckdir)
        assert len(svc2.scheduler) == 2
        for rid in rids:
            assert svc2.poll(rid)["status"] == "pending"
        assert svc2.submit(SPECS[2]) == max(rids) + 1  # rid counter resumes
        svc2.shutdown()
        svc1.shutdown()

    def test_slot_latency_fault_delays_solve(self):
        import time as _time

        svc = _manual_service(_get_ops())
        rid = svc.submit(SPECS[0])
        with faults.inject("serve.slot_latency", value=0.2):
            t0 = _time.perf_counter()
            _drain_one_slot(svc)
            dt = _time.perf_counter() - t0
        assert dt >= 0.2
        assert svc.result(rid, timeout=5.0)["status"] == "done"
        svc.shutdown()
