"""Serving subsystem: batched multi-problem exactness vs independent single
runs (energies and singular values), scheduler grouping / power-of-two slot
padding, plan-cache thread-safety, queue backpressure, and the end-to-end
service worker (subprocess: XLA compilation with a live secondary thread is
fragile late in a big shared process on this jaxlib)."""
import os
import subprocess
import sys
import textwrap
import threading

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import run_dmrg
from repro.dist import DecompositionEngine, cache_stats
from repro.dist.plan import _SignatureLRU
from repro.serve import (
    DEVICE_LOCK,
    BatchScheduler,
    DMRGService,
    ProblemSpec,
    ServeQueueFull,
    build_problem,
    group_key,
    run_dmrg_multi,
    svd_split_multi,
)
from repro.serve.stacked import stack_tensors

from test_decomp import rand_theta


def _solve_single(spec, mpo, space):
    """Reference: one independent run over the same prebuilt operator.

    Holds DEVICE_LOCK so a live service worker never compiles concurrently
    with this run (jaxlib < 0.5 segfaults on concurrent XLA compilation).
    """
    with DEVICE_LOCK:
        return run_dmrg(
            space,
            None,
            spec.n_sites,
            bond_schedule=spec.bond_schedule,
            sweeps_per_bond=spec.sweeps_per_bond,
            davidson_iters=spec.davidson_iters,
            cutoff=spec.cutoff,
            mpo=mpo,
            algo="batched",
            jit_matvec=True,
        )


@pytest.mark.x64
class TestMultiProblemCore:
    @settings(max_examples=2, deadline=None)
    @given(
        j0=st.floats(min_value=0.6, max_value=1.4),
        h0=st.floats(min_value=0.1, max_value=0.5),
    )
    def test_batch_matches_independent_singles(self, j0, h0):
        """Property: a batch of B problems with varied (J, h) reproduces B
        independent single-problem runs to 1e-10."""
        pairs = [(j0, h0), (0.9 * j0, h0 + 0.15), (1.1 * j0, h0 + 0.3)]
        specs = [
            ProblemSpec.make(
                "heisenberg", 6, J=j, h=h, max_bond=8, davidson_iters=5
            )
            for j, h in pairs
        ]
        built = [build_problem(s) for s in specs]
        space = built[0][0]
        mpos = [m for _, m in built]
        res = run_dmrg_multi(
            space,
            6,
            mpos,
            bond_schedule=specs[0].bond_schedule,
            sweeps_per_bond=2,
            davidson_iters=5,
        )
        for b, spec in enumerate(specs):
            ref = _solve_single(spec, mpos[b], space)
            assert abs(float(res.energies[b]) - ref.energy) < 1e-10

    def test_structure_mismatch_rejected(self):
        """Problems whose MPOs differ in block structure cannot share a batch
        axis — run_dmrg_multi must refuse rather than compute garbage."""
        s_chain = ProblemSpec.make("heisenberg", 6, J=1.0, h=0.3)
        s_ladder = ProblemSpec.make("j1j2_ladder", 6, J1=1.0, J2=0.5)
        space, mpo_a = build_problem(s_chain)
        _, mpo_b = build_problem(s_ladder)
        with pytest.raises(ValueError, match="structure"):
            run_dmrg_multi(space, 6, [mpo_a, mpo_b], bond_schedule=(8,))

    def test_stacked_svals_match_per_problem_svd(self):
        """svd_split_multi singular values equal per-problem engine.svd_split
        for every problem and sector; phantom slots are exact zeros."""
        base = rand_theta(7)
        thetas = [
            type(base).random(
                base.indices, key=jax.random.PRNGKey(100 + b), charge=base.charge
            )
            for b in range(3)
        ]
        stacked = stack_tensors(thetas)
        _, _, svals_multi, errs = svd_split_multi(
            stacked, 2, max_bond=6, cutoff=1e-12
        )
        engine = DecompositionEngine()
        for b, theta in enumerate(thetas):
            _, _, svals_one, err_one = engine.svd_split(
                theta, 2, max_bond=6, cutoff=1e-12
            )
            assert abs(float(errs[b]) - err_one) < 1e-10
            for q, col in svals_multi.items():
                ref = np.asarray(svals_one.get(q, np.zeros(0)))
                got = np.asarray(col[b])
                assert got[: len(ref)] == pytest.approx(ref, abs=1e-10)
                assert np.all(np.abs(got[len(ref):]) < 1e-14)


class TestScheduler:
    def _spec(self, **kw):
        return ProblemSpec.make("heisenberg", kw.pop("n", 6), **kw)

    def test_group_key_ignores_values_catches_structure(self):
        sa = self._spec(J=0.8, h=0.3)
        sb = self._spec(J=1.2, h=0.45)
        # degenerate h=0 keeps the (zero-block) field channel: same structure,
        # same group — the sweep endpoint batches with the rest
        sc = self._spec(J=1.0, h=0.0)
        sd = self._spec(J=1.0, h=0.3, n=8)
        se = ProblemSpec.make("j1j2_ladder", 6, J1=1.0, J2=0.5)
        ka = group_key(sa, build_problem(sa)[1])
        kb = group_key(sb, build_problem(sb)[1])
        kc = group_key(sc, build_problem(sc)[1])
        kd = group_key(sd, build_problem(sd)[1])
        ke = group_key(se, build_problem(se)[1])
        assert ka == kb == kc
        assert ka != kd          # different chain length
        assert ka != ke          # different model -> different MPO structure

    def test_power_of_two_slot_padding(self):
        sched = BatchScheduler(max_batch=8)
        spec = self._spec(J=1.0, h=0.3)
        for rid in range(3):
            sched.add(("g",), rid, spec, "space", f"mpo{rid}")
        slot = sched.next_batch()
        assert slot.rids == [0, 1, 2]
        assert slot.slot_size == 4          # padded 3 -> 4
        assert slot.mpos == ["mpo0", "mpo1", "mpo2", "mpo2"]  # tail duplicate
        assert slot.fill_ratio == pytest.approx(0.75)
        assert len(sched) == 0 and sched.next_batch() is None

    def test_oldest_head_group_served_first(self):
        sched = BatchScheduler(max_batch=2)
        spec = self._spec(J=1.0)
        sched.add(("a",), 0, spec, "sp", "m0")
        sched.add(("b",), 1, spec, "sp", "m1")
        sched.add(("a",), 2, spec, "sp", "m2")
        first = sched.next_batch()
        assert first.key == ("a",) and first.rids == [0, 2]
        second = sched.next_batch()
        assert second.key == ("b",) and second.rids == [1]
        assert second.slot_size == 1


class TestPlanCacheThreadSafety:
    def test_concurrent_get_consistent_stats(self):
        """Hammer one small cache from many threads: every signature must
        resolve to a single shared plan object, and the counters must add up
        (hits + misses == lookups) with evictions actually counted."""
        cache = _SignatureLRU(maxsize=4)
        n_threads, n_iter, n_sigs = 8, 300, 12
        built = []
        build_lock = threading.Lock()
        seen = [dict() for _ in range(n_threads)]

        def worker(tid):
            for i in range(n_iter):
                sig = ("sig", (tid + i) % n_sigs)

                def build():
                    obj = object()
                    with build_lock:
                        built.append(obj)
                    return obj

                plan = cache._get(sig, build)
                seen[tid][sig] = plan

        threads = [
            threading.Thread(target=worker, args=(t,)) for t in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st_ = cache.stats()
        assert st_["hits"] + st_["misses"] == n_threads * n_iter
        assert st_["misses"] == len(built)
        assert st_["size"] <= 4
        assert st_["evictions"] == st_["misses"] - st_["size"]
        assert st_["evictions"] > 0

    def test_cache_stats_shape(self):
        out = cache_stats()
        assert set(out) == {
            "plan_cache", "decomp_plan_cache", "env_plan_cache", "plan_store",
        }
        for k in ("plan_cache", "decomp_plan_cache", "env_plan_cache"):
            assert set(out[k]) == {
                "hits", "misses", "evictions", "size", "builds",
            }


class TestService:
    def test_backpressure_queue_full(self):
        svc = DMRGService(max_batch=2, max_queue=2, start=False)
        spec = ProblemSpec.make("heisenberg", 4, J=1.0, h=0.3)
        svc.submit(spec, timeout=1.0)
        svc.submit(spec, timeout=1.0)
        with pytest.raises(ServeQueueFull):
            svc.submit(spec, timeout=0.05)
        assert svc.stats()["pending"] == 2
        svc.shutdown()

    def test_unknown_request_id(self):
        svc = DMRGService(start=False)
        with pytest.raises(KeyError):
            svc.poll(99)
        with pytest.raises(KeyError):
            svc.result(99, timeout=0.01)
        svc.shutdown()

    def test_unknown_model_rejected_at_submit(self):
        svc = DMRGService(start=False)
        with pytest.raises(ValueError, match="unknown model"):
            svc.submit(ProblemSpec.make("not-a-model", 4))
        svc.shutdown()

    @pytest.mark.slow
    def test_end_to_end_correct_energies(self, tmp_path):
        """Full service path — queue, worker thread, warmed zero-retrace
        steady state, energies vs independent singles — in its OWN process:
        on jaxlib 0.4.x, XLA compilation with a live secondary thread can
        segfault late in a large shared pytest process (it is rock-solid in
        a fresh interpreter, which is also how the serve CLI runs)."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        code = textwrap.dedent(f"""\
        import os
        os.environ["JAX_ENABLE_X64"] = "1"
        import sys
        sys.path.insert(0, r"{os.path.abspath(src)}")
        from repro.core import run_dmrg
        from repro.serve import DEVICE_LOCK, DMRGService, ProblemSpec
        from repro.serve.problems import build_problem

        svc = DMRGService(max_batch=2, max_queue=8, batch_wait_s=0.05)
        specs = [
            ProblemSpec.make(
                "heisenberg", 6, J=j, h=0.3, max_bond=8, davidson_iters=5
            )
            for j in (0.9, 1.0, 1.1)
        ]
        # the documented serving pattern: warm on the calling thread so the
        # worker replays compiled code only
        svc.warmup(specs[0], sizes=(1, 2))
        rids = [svc.submit(s, timeout=5.0) for s in specs]
        recs = [svc.result(rid, timeout=600.0) for rid in rids]
        for rec, spec in zip(recs, specs):
            assert rec["status"] == "done"
            space, mpo = build_problem(spec)
            with DEVICE_LOCK:
                ref = run_dmrg(
                    space, None, spec.n_sites,
                    bond_schedule=spec.bond_schedule,
                    sweeps_per_bond=spec.sweeps_per_bond,
                    davidson_iters=spec.davidson_iters, cutoff=spec.cutoff,
                    mpo=mpo, algo="batched", jit_matvec=True,
                )
            diff = abs(rec["energy"] - ref.energy)
            assert diff < 1e-10, (rec["energy"], ref.energy)
        st = svc.stats()
        assert st["completed"] == 3 and st["failed"] == 0, st
        assert st["pending"] == 0, st
        assert st["retraces"] == 0, st       # warmed group replays only
        assert st["problems_per_sec"] > 0, st
        assert 0.0 < st["batch_fill_ratio"] <= 1.0, st
        assert set(st["plan_caches"]) >= {{
            "plan_cache", "decomp_plan_cache", "env_plan_cache", "engines"
        }}, st
        svc.shutdown()
        print("SERVE_E2E_OK")
        """)
        script = tmp_path / "serve_e2e.py"
        script.write_text(code)
        proc = subprocess.run(
            [sys.executable, str(script)],
            capture_output=True,
            text=True,
            timeout=900,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "SERVE_E2E_OK" in proc.stdout

    @pytest.mark.x64
    def test_failed_slot_bisects_and_recovers(self):
        """A slot whose problems turn out incompatible no longer fails (or
        hangs) every request in it: the unmasked failure bisects the slot
        and each half solves clean on its own."""
        svc = DMRGService(max_batch=2, start=False)
        s_chain = ProblemSpec.make("heisenberg", 6, J=1.0, h=0.3)
        s_ladder = ProblemSpec.make("j1j2_ladder", 6, J1=1.0, J2=0.5)
        space, mpo_a = build_problem(s_chain)
        _, mpo_b = build_problem(s_ladder)
        # bypass group_key on purpose to force a mixed-structure slot
        with svc._cv:
            for rid, (sp, mpo) in enumerate(
                [(s_chain, mpo_a), (s_ladder, mpo_b)]
            ):
                svc._requests[rid] = {"status": "running", "spec": sp,
                                      "submitted": 0.0, "retries": 0,
                                      "space": space, "mpo": mpo,
                                      "key": ("forced",)}
                svc.scheduler.add(("forced",), rid, sp, space, mpo)
        slot = svc.scheduler.next_batch()
        svc._run_slot(slot)
        r0 = svc.result(0, timeout=1.0)
        r1 = svc.result(1, timeout=1.0)
        assert r0["status"] == "done" and r1["status"] == "done"
        st = svc.stats()
        assert st["bisections"] == 1
        assert st["completed"] == 2
        assert st["failed"] == 0
        svc.shutdown()
