"""Distributed contraction engine: plan-cache semantics, plan-executed
backends vs the seed per-call algorithms (block-for-block), engine-driven
DMRG vs the seed sweep, and an 8-fake-device mesh-sharded sweep."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import run_dmrg
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space
from repro.dist import ContractionEngine, PlanCache, get_plan
from repro.dist.plan import ContractionPlan, plan_signature
from repro.tensor import (
    BlockSparseTensor,
    Index,
    OUT,
    contract,
    contract_block_csr,
    contract_dense,
)


def rand_index(rng, nq=1, max_sectors=3, max_dim=4, flow=OUT):
    ns = rng.integers(1, max_sectors + 1)
    charges = rng.choice(np.arange(-2, 3), size=(8, nq), replace=True)
    charges = [tuple(int(c) for c in q) for q in charges]
    uniq = []
    for q in charges:
        if q not in uniq:
            uniq.append(q)
    uniq = uniq[:ns]
    return Index(tuple((q, int(rng.integers(1, max_dim + 1))) for q in uniq), flow)


def rand_pair(seed, nq=1):
    rng = np.random.default_rng(seed)
    shared = rand_index(rng, nq=nq)
    ia = rand_index(rng, nq=nq)
    ib = rand_index(rng, nq=nq)
    A = BlockSparseTensor.random([ia, shared], key=jax.random.PRNGKey(seed))
    B = BlockSparseTensor.random([shared.dual(), ib], key=jax.random.PRNGKey(seed + 1))
    return A, B


AX = ((1,), (0,))


class TestPlanCache:
    def test_hit_miss_semantics(self):
        A, B = rand_pair(0)
        cache = PlanCache()
        p1 = cache.get(A, B, AX)
        assert cache.stats() == {"hits": 0, "misses": 1, "evictions": 0, "size": 1, "builds": 1}
        p2 = cache.get(A, B, AX)
        assert p2 is p1
        assert cache.stats() == {"hits": 1, "misses": 1, "evictions": 0, "size": 1, "builds": 1}
        # same structure, different numbers -> hit (signature is structural)
        A2 = BlockSparseTensor(
            A.indices, {k: 2.0 * b for k, b in A.blocks.items()}, A.charge
        )
        assert cache.get(A2, B, AX) is p1
        # different structure -> miss
        C, D = rand_pair(5)
        if plan_signature(C, D, AX) != plan_signature(A, B, AX):
            cache.get(C, D, AX)
            assert cache.misses == 2

    def test_lru_eviction(self):
        cache = PlanCache(maxsize=2)
        pairs = [rand_pair(s) for s in (0, 5, 9)]
        sigs = {plan_signature(a, b, AX) for a, b in pairs}
        if len(sigs) < 3:
            pytest.skip("random structures collided")
        for a, b in pairs:
            cache.get(a, b, AX)
        assert len(cache) == 2
        # first pair was evicted -> rebuilt on next get
        cache.get(*pairs[0], AX)
        assert cache.misses == 4

    def test_signature_ignores_index_names(self):
        A, B = rand_pair(3)
        renamed = BlockSparseTensor(
            tuple(Index(ix.sectors, ix.flow, "other") for ix in A.indices),
            A.blocks,
            A.charge,
        )
        assert plan_signature(A, B, AX) == plan_signature(renamed, B, AX)

    def test_plan_pair_table_matches_list_algorithm(self):
        A, B = rand_pair(1)
        plan = ContractionPlan.build(A, B, AX)
        ref = contract(A, B, AX)
        assert set(k for _, _, k in plan.pairs) == set(ref.blocks.keys())
        assert plan.out_indices == ref.indices
        assert plan.out_charge == ref.charge


class TestPlanExecutionEquivalence:
    """Plan-executed backends match the seed per-call algorithms
    block-for-block on random charged tensors."""

    @pytest.mark.parametrize("seed", range(8))
    def test_list_block_for_block(self, seed):
        A, B = rand_pair(seed, nq=1 + seed % 2)
        eng = ContractionEngine(backend="list", cache=PlanCache())
        got, ref = eng(A, B, AX), contract(A, B, AX)
        assert set(got.blocks) == set(ref.blocks)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=1e-13
            )

    @pytest.mark.parametrize("seed", range(4))
    def test_dense_block_for_block(self, seed):
        A, B = rand_pair(seed)
        eng = ContractionEngine(backend="dense", cache=PlanCache())
        got, ref = eng(A, B, AX), contract_dense(A, B, AX)
        assert set(got.blocks) == set(ref.blocks)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=1e-13
            )

    @pytest.mark.parametrize("seed", range(4))
    def test_csr_block_for_block(self, seed):
        A, B = rand_pair(seed)
        eng = ContractionEngine(backend="csr", cache=PlanCache(), use_kernel=False)
        got = eng(A, B, AX)
        ref = contract_block_csr(A, B, AX, use_kernel=False)
        assert set(got.blocks) == set(ref.blocks)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=1e-12
            )

    @pytest.mark.x64
    def test_higher_order_all_backends(self):
        rng = np.random.default_rng(7)
        i1, i2, i3 = (rand_index(rng) for _ in range(3))
        A = BlockSparseTensor.random([i1, i2, i3], key=jax.random.PRNGKey(0))
        B = BlockSparseTensor.random(
            [i2.dual(), i3.dual(), i1], key=jax.random.PRNGKey(1)
        )
        ax = ((1, 2), (0, 1))
        ref = contract(A, B, axes=ax).to_dense()
        for backend in ("list", "dense", "csr", "batched", "auto"):
            eng = ContractionEngine(
                backend=backend, cache=PlanCache(), use_kernel=False
            )
            np.testing.assert_allclose(
                np.asarray(eng(A, B, ax).to_dense()), np.asarray(ref), atol=1e-12
            )

    def test_auto_choice_and_counts(self):
        A, B = rand_pair(2)
        eng = ContractionEngine(backend="auto", cache=PlanCache())
        plan = get_plan(A, B, AX, cache=eng.cache)
        assert eng.choose_backend(plan) in ("list", "dense")
        eng(A, B, AX)
        assert sum(eng.backend_counts.values()) == 1

    def test_jit_matvec_reuses_plans(self):
        A, B = rand_pair(4)
        cache = PlanCache()
        eng = ContractionEngine(backend="list", cache=cache)
        jf = jax.jit(lambda a, b: eng(a, b, AX))
        C1 = jf(A, B).to_dense()
        C2 = jf(A, B).to_dense()  # second call: trace cache, no new plans
        np.testing.assert_allclose(np.asarray(C1), np.asarray(C2), atol=0)
        np.testing.assert_allclose(
            np.asarray(C1), np.asarray(contract(A, B, AX).to_dense()), atol=1e-12
        )
        assert cache.misses == 1


class TestEngineDMRG:
    def _system(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        return sp, terms

    @pytest.mark.x64
    def test_planned_energy_equals_seed_list(self):
        sp, terms = self._system()
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=2, davidson_iters=6)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        planned = run_dmrg(sp, terms, 6, algo="list", **kw)
        assert abs(seed.energy - planned.energy) < 1e-10
        for s_seed, s_plan in zip(seed.sweep_stats, planned.sweep_stats):
            assert abs(s_seed.energy - s_plan.energy) < 1e-10

    @pytest.mark.x64
    def test_jit_matvec_energy_equals_seed(self):
        sp, terms = self._system()
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        jit = run_dmrg(sp, terms, 6, algo="list", jit_matvec=True, **kw)
        assert abs(seed.energy - jit.energy) < 1e-10

    @pytest.mark.x64
    def test_auto_backend_energy_equals_seed(self):
        sp, terms = self._system()
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        auto = run_dmrg(sp, terms, 6, algo="auto", **kw)
        assert abs(seed.energy - auto.energy) < 1e-10

    def test_engine_features_rejected_for_bare_contractors(self):
        """Bare seed contractors can't gather sharded blocks (deadlock) or
        jit the planned matvec — must fail loudly, not hang / ignore."""
        from repro.dist import BlockShardPolicy, make_block_mesh

        sp, terms = self._system()
        kw = dict(bond_schedule=(8,), sweeps_per_bond=1, davidson_iters=2)
        with pytest.raises(ValueError, match="shard_policy"):
            run_dmrg(sp, terms, 6, algo="list_unplanned",
                     shard_policy=BlockShardPolicy(make_block_mesh()), **kw)
        with pytest.raises(ValueError, match="jit_matvec"):
            run_dmrg(sp, terms, 6, algo="list_unplanned", jit_matvec=True, **kw)


@pytest.mark.slow
class TestShardedSweep:
    """8-fake-device mesh-sharded sweep == single-device sweep (subprocess:
    the XLA device-count flag must be set before jax initializes)."""

    def test_sharded_energy_matches_single_device(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        code = textwrap.dedent(f"""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_ENABLE_X64"] = "1"
        import sys
        sys.path.insert(0, r"{os.path.abspath(src)}")
        import jax
        assert jax.device_count() == 8, jax.device_count()
        from repro.core import run_dmrg
        from repro.core.models import heisenberg_j1j2_terms
        from repro.core.siteops import spin_half_space
        from repro.dist import BlockShardPolicy, make_block_mesh

        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=1, davidson_iters=4)
        single = run_dmrg(sp, terms, 6, algo="list", **kw)
        policy = BlockShardPolicy(make_block_mesh())
        assert policy.mesh.shape["row"] * policy.mesh.shape["col"] == 8
        sharded = run_dmrg(sp, terms, 6, algo="list", shard_policy=policy, **kw)
        diff = abs(single.energy - sharded.energy)
        assert diff < 1e-10, (single.energy, sharded.energy)
        print(f"SHARDED_OK diff={{diff:.2e}}")
        """)
        script = tmp_path / "sharded_sweep.py"
        script.write_text(code)
        proc = subprocess.run(
            [sys.executable, str(script)],
            capture_output=True,
            text=True,
            timeout=900,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "SHARDED_OK" in proc.stdout
