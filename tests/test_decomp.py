"""Decomposition engine: planned batched SVD vs the seed per-sector loop
(block-for-block up to sign gauge, gauge-invariant products exactly),
truncation-error accounting, absorb gauge agreement, deterministic exact-tie
truncation, the randomized path, and plan-cache / retrace semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import run_dmrg
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space
from repro.dist import ContractionEngine, DecompositionEngine, DecompPlanCache
from repro.dist.decomp import svd_split_planned
from repro.dist.plan import DecompositionPlan, decomp_signature
from repro.tensor import (
    BlockSparseTensor,
    IN,
    Index,
    OUT,
    contract,
    svd_split,
    svd_split_unplanned,
)

from test_dist import rand_index


def rand_theta(seed, nq=1, n_modes=4, n_row_modes=2):
    """Random 4-mode theta with a bra-like first mode, as in a DMRG pair."""
    for s in range(seed, seed + 50):
        rng = np.random.default_rng(s)
        flows = (IN,) + (OUT,) * (n_modes - 1)
        ixs = [rand_index(rng, nq=nq, flow=f) for f in flows]
        t = BlockSparseTensor.random(ixs, key=jax.random.PRNGKey(s))
        if t.num_blocks > 1:
            return t
    raise RuntimeError("no non-trivial theta found")


def recon(U, V, n_row_modes=2):
    """Dense U·V product over the bond — the gauge-invariant part of a split."""
    return np.asarray(
        contract(U, V, axes=((n_row_modes,), (0,))).to_dense()
    )


def align_sign_gauge(U_ref, V_ref, U, V):
    """Flip U columns / V rows of (U, V) so the bond gauge matches the
    reference split.  LAPACK's singular-vector sign choice is unspecified,
    so two numerically different-but-equal computations may differ by a
    diag(±1) on the bond; this removes exactly that freedom."""
    bond_ax = U.ndim - 1
    bond = U.indices[bond_ax]
    u_blocks, v_blocks = dict(U.blocks), dict(V.blocks)
    for s in range(bond.num_sectors):
        m = bond.sector_dim(s)
        dots = np.zeros(m)
        for k, b in U.blocks.items():
            if k[bond_ax] != s or k not in U_ref.blocks:
                continue
            dots += np.sum(
                np.asarray(U_ref.blocks[k]).reshape(-1, m)
                * np.asarray(b).reshape(-1, m),
                axis=0,
            )
        flip = np.where(dots < 0, -1.0, 1.0)
        for k in list(u_blocks):
            if k[bond_ax] == s:
                u_blocks[k] = u_blocks[k] * flip
        for k in list(v_blocks):
            if k[0] == s:
                v_blocks[k] = v_blocks[k] * flip.reshape((-1,) + (1,) * (V.ndim - 1))
    return (
        BlockSparseTensor(U.indices, u_blocks, U.charge),
        BlockSparseTensor(V.indices, v_blocks, V.charge),
    )


class TestDecompPlan:
    def test_cache_hit_miss_semantics(self):
        theta = rand_theta(0)
        cache = DecompPlanCache()
        p1 = cache.get(theta, 2)
        assert cache.stats() == {"hits": 0, "misses": 1, "evictions": 0, "size": 1, "builds": 1}
        # same structure, different numbers -> hit (signature is structural)
        theta2 = BlockSparseTensor(
            theta.indices, {k: 2.0 * b for k, b in theta.blocks.items()}, theta.charge
        )
        assert cache.get(theta2, 2) is p1
        assert cache.stats()["hits"] == 1
        # a different split point is a different plan
        cache.get(theta, 1)
        assert cache.misses == 2
        assert decomp_signature(theta, 1) != decomp_signature(theta, 2)

    def test_gather_tables_reproduce_seed_assembly(self):
        """The plan's single-gather assembly must produce exactly the padded
        embedding of the sector matrices the seed builds block-by-block."""
        theta = rand_theta(3)
        plan = DecompositionPlan.build(theta, 2)
        flat = np.concatenate(
            [np.asarray(theta.blocks[k]).reshape(-1) for k in plan.block_order]
            + [np.zeros(1)]
        )
        for bucket in plan.buckets:
            mats = flat[bucket.gather]
            for slot, si in enumerate(bucket.sectors):
                sec = plan.sectors[si]
                # rebuild the seed's [R, C] sector matrix
                ref = np.zeros((sec.R, sec.C))
                import repro.tensor.qn as qn

                for k in theta.blocks:
                    rk, ck = k[:2], k[2:]
                    if rk not in sec.row_keys or ck not in sec.col_keys:
                        continue
                    # only blocks whose fused row charge is this sector
                    qk = qn.qzero(theta.indices[0].nq)
                    for ix, sct in zip(theta.indices[:2], rk):
                        qk = qn.qadd(qk, qn.qscale(ix.charge(sct), ix.flow))
                    if qk != sec.q:
                        continue
                    ri = sec.row_keys.index(rk)
                    ci = sec.col_keys.index(ck)
                    ref[
                        sec.roffs[ri] : sec.roffs[ri] + sec.rdims[ri],
                        sec.coffs[ci] : sec.coffs[ci] + sec.cdims[ci],
                    ] = np.asarray(theta.blocks[k]).reshape(
                        sec.rdims[ri], sec.cdims[ci]
                    )
                got = mats[slot]
                np.testing.assert_allclose(got[: sec.R, : sec.C], ref, atol=0)
                # padding region is exactly zero
                assert np.all(got[sec.R :, :] == 0) and np.all(got[:, sec.C :] == 0)

    def test_every_sector_in_exactly_one_bucket_slot(self):
        theta = rand_theta(7)
        plan = DecompositionPlan.build(theta, 2)
        seen = sorted(si for b in plan.buckets for si in b.sectors)
        assert seen == list(range(plan.num_sectors))
        for si, sec in enumerate(plan.sectors):
            b = plan.buckets[sec.bucket]
            assert b.sectors[sec.slot] == si
            assert b.rp >= sec.R and b.cp >= sec.C


@pytest.mark.x64
class TestPlannedEqualsUnplanned:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), max_bond=st.integers(1, 12))
    def test_property_block_for_block_up_to_gauge(self, seed, max_bond):
        theta = rand_theta(seed)
        ref = svd_split_unplanned(theta, 2, max_bond=max_bond, cutoff=0.0)
        got = svd_split(theta, 2, max_bond=max_bond, cutoff=0.0)
        U_r, V_r, sv_r, err_r = ref
        U_p, V_p, sv_p, err_p = got
        # identical bond structure, block keys and singular values
        assert U_p.indices == U_r.indices and V_p.indices == V_r.indices
        assert set(U_p.blocks) == set(U_r.blocks)
        assert set(V_p.blocks) == set(V_r.blocks)
        assert set(sv_p) == set(sv_r)
        for q in sv_r:
            np.testing.assert_allclose(
                np.asarray(sv_p[q]), np.asarray(sv_r[q]), atol=1e-10
            )
        assert abs(err_p - err_r) < 1e-10
        # block-for-block after removing the singular-vector sign freedom
        U_a, V_a = align_sign_gauge(U_r, V_r, U_p, V_p)
        for k in U_r.blocks:
            np.testing.assert_allclose(
                np.asarray(U_a.blocks[k]), np.asarray(U_r.blocks[k]), atol=1e-10
            )
        for k in V_r.blocks:
            np.testing.assert_allclose(
                np.asarray(V_a.blocks[k]), np.asarray(V_r.blocks[k]), atol=1e-10
            )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), max_bond=st.integers(1, 10))
    def test_property_trunc_err_is_squared_reconstruction_error(
        self, seed, max_bond
    ):
        theta = rand_theta(seed)
        dense = np.asarray(theta.to_dense())
        for split in (svd_split, svd_split_unplanned):
            U, V, _, err = split(theta, 2, max_bond=max_bond, cutoff=0.0)
            actual = float(np.sum(np.abs(recon(U, V) - dense) ** 2))
            np.testing.assert_allclose(actual, err, rtol=1e-8, atol=1e-12)

    def test_absorb_left_right_agree_up_to_gauge(self):
        theta = rand_theta(5)
        U_r, V_r, sv_r, err_r = svd_split(theta, 2, max_bond=6, absorb="right")
        U_l, V_l, sv_l, err_l = svd_split(theta, 2, max_bond=6, absorb="left")
        # the absorbed product, the retained sectors, the singular values and
        # the truncation error are all gauge-invariant and must agree
        np.testing.assert_allclose(recon(U_r, V_r), recon(U_l, V_l), atol=1e-11)
        assert U_r.indices[-1] == U_l.indices[-1]
        assert err_r == err_l
        for q in sv_r:
            np.testing.assert_allclose(
                np.asarray(sv_r[q]), np.asarray(sv_l[q]), atol=1e-12
            )
        # and each side is isometric on its unabsorbed factor
        gram = contract(U_l.conj(), U_l, axes=((0, 1), (0, 1))).to_dense()
        s_sq = np.sort(np.diag(np.asarray(gram)))  # U_l carries s: diag = s^2
        all_s = np.sort(np.concatenate([np.asarray(v) for v in sv_l.values()]))
        np.testing.assert_allclose(s_sq, all_s**2, atol=1e-11)

    def test_no_absorb_returns_isometries(self):
        theta = rand_theta(9)
        U, V, _, _ = svd_split(theta, 2, max_bond=8, absorb="none")
        gram_u = np.asarray(
            contract(U.conj(), U, axes=((0, 1), (0, 1))).to_dense()
        )
        gram_v = np.asarray(contract(V, V.conj(), axes=((1, 2), (1, 2))).to_dense())
        np.testing.assert_allclose(gram_u, np.eye(len(gram_u)), atol=1e-11)
        np.testing.assert_allclose(gram_v, np.eye(len(gram_v)), atol=1e-11)


class TestTieBreak:
    def _tied_theta(self):
        """Two charge sectors whose sector matrices have identical spectra
        {1.0, 0.5} — every singular value is exactly tied across sectors."""
        row = Index((((0,), 2), ((1,), 2)), IN)
        col = Index((((0,), 2), ((1,), 2)), OUT)
        d = jnp.asarray(np.diag([1.0, 0.5]))
        return BlockSparseTensor([row, col], {(0, 0): d, (1, 1): d})

    def test_planned_exact_ties_keep_at_most_max_bond(self):
        theta = self._tied_theta()
        U, V, svals, _ = svd_split(theta, 1, max_bond=3, cutoff=0.0)
        assert U.indices[-1].dim == 3  # deterministic: 2 from sector 0, 1 from 1
        kept = {q: len(np.asarray(v)) for q, v in svals.items()}
        assert sum(kept.values()) == 3

    def test_seed_exact_ties_can_exceed_max_bond(self):
        """Documents the seed semantics the planned path fixes: every value
        tied at the threshold is kept, overshooting max_bond."""
        theta = self._tied_theta()
        U, _, _, _ = svd_split_unplanned(theta, 1, max_bond=3, cutoff=0.0)
        assert U.indices[-1].dim == 4

    def test_tie_break_is_deterministic(self):
        theta = self._tied_theta()
        a = svd_split(theta, 1, max_bond=3, cutoff=0.0)
        b = svd_split(theta, 1, max_bond=3, cutoff=0.0)
        for k in a[0].blocks:
            np.testing.assert_allclose(
                np.asarray(a[0].blocks[k]), np.asarray(b[0].blocks[k]), atol=0
            )


class TestRandomizedPath:
    def _decaying_theta(self, R=96, C=80):
        """Single-sector matrix with an exponentially decaying spectrum (the
        regime where a sketch captures the top of the spectrum accurately)."""
        rng = np.random.default_rng(0)
        u, _ = np.linalg.qr(rng.normal(size=(R, R)))
        v, _ = np.linalg.qr(rng.normal(size=(C, C)))
        s = 2.0 ** -np.arange(min(R, C), dtype=np.float64)
        dense = (u[:, : len(s)] * s) @ v[: len(s), :]
        row = Index((((0,), R),), IN)
        col = Index((((0,), C),), OUT)
        return BlockSparseTensor([row, col], {(0, 0): jnp.asarray(dense)})

    @pytest.mark.x64
    def test_randomized_matches_exact_top_of_spectrum(self):
        theta = self._decaying_theta()
        exact = DecompositionEngine(cache=DecompPlanCache(), method="svd")
        rand = DecompositionEngine(cache=DecompPlanCache(), method="randomized")
        max_bond = 8
        _, _, sv_e, err_e = exact.svd_split(theta, 1, max_bond, cutoff=0.0)
        _, _, sv_r, err_r = rand.svd_split(theta, 1, max_bond, cutoff=0.0)
        assert rand.rsvd_buckets == 1 and exact.rsvd_buckets == 0
        np.testing.assert_allclose(
            np.asarray(sv_r[(0,)]), np.asarray(sv_e[(0,)]), rtol=1e-8
        )
        # the sketch only sees the top of the spectrum, so its trunc_err is a
        # lower bound on the exact discarded weight
        assert err_r <= err_e + 1e-12

    def test_randomized_falls_back_to_exact_when_sketch_covers_rank(self):
        theta = rand_theta(4)  # tiny sectors: sketch >= min(R, C) everywhere
        eng = DecompositionEngine(cache=DecompPlanCache(), method="randomized")
        U, V, _, err = eng.svd_split(theta, 2, max_bond=8, cutoff=0.0)
        assert eng.rsvd_buckets == 0
        ref = svd_split_unplanned(theta, 2, max_bond=8, cutoff=0.0)
        np.testing.assert_allclose(recon(U, V), recon(ref[0], ref[1]), atol=1e-10)
        assert abs(err - ref[3]) < 1e-10

    def test_auto_cost_model_prefers_rsvd_only_on_large_buckets(self):
        eng = DecompositionEngine(cache=DecompPlanCache(), method="auto")
        small = eng.cache.get(rand_theta(4), 2)
        methods_small, _ = eng._bucket_methods(small, 8)
        assert set(methods_small) == {"svd"}
        big = eng.cache.get(self._decaying_theta(512, 512), 1)
        methods_big, sketch = eng._bucket_methods(big, 8)
        assert "rsvd" in methods_big and sketch == 8 + eng.rsvd_oversample


class TestEngineIntegration:
    def test_contraction_engine_svd_split_and_stats(self):
        theta = rand_theta(2)
        eng = ContractionEngine(backend="batched")
        eng.decomp = DecompositionEngine(cache=DecompPlanCache())
        U, V, _, _ = eng.svd_split(theta, 2, max_bond=8)
        st_ = eng.stats()["decomp"]
        assert st_["svd_calls"] == 1
        assert st_["svd_flops"] > 0 and st_["svd_seconds"] > 0
        assert st_["sectors"] >= st_["buckets"] >= 1
        assert st_["plan_cache"]["misses"] == 1

    def test_compile_once_no_retrace_on_same_structure(self):
        theta = rand_theta(6)
        eng = DecompositionEngine(cache=DecompPlanCache())
        eng.svd_split(theta, 2, max_bond=8)
        traces = eng.jit_retraces  # SVD core + output-slice core compiled
        assert traces >= 1
        theta2 = BlockSparseTensor(
            theta.indices,
            {k: 1.5 * b for k, b in theta.blocks.items()},
            theta.charge,
        )
        eng.svd_split(theta2, 2, max_bond=8)  # same structure: cached compile
        assert eng.jit_retraces == traces
        assert eng.cache.stats() == {"hits": 1, "misses": 1, "evictions": 0, "size": 1, "builds": 1}

    def test_tracer_input_raises(self):
        theta = rand_theta(1)
        eng = DecompositionEngine(cache=DecompPlanCache())

        def f(t):
            return eng.svd_split(t, 2, max_bond=4)[3]

        with pytest.raises(TypeError, match="concrete"):
            jax.jit(f)(theta)

    @pytest.mark.x64
    def test_dmrg_planned_svd_energy_equals_full_seed(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4)
        seed = run_dmrg(
            sp, terms, 6, algo="list_unplanned", svd_method="unplanned", **kw
        )
        planned = run_dmrg(sp, terms, 6, algo="batched", **kw)
        auto = run_dmrg(sp, terms, 6, algo="batched", svd_method="auto", **kw)
        assert abs(seed.energy - planned.energy) < 1e-10
        assert abs(seed.energy - auto.energy) < 1e-10
        # the sweep reports the decomposition stage separately
        assert planned.sweep_stats[-1].svd_seconds > 0

    def test_svd_method_rejected_for_bare_contractors(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        with pytest.raises(ValueError, match="svd_method"):
            run_dmrg(
                sp, terms, 6, algo="list_unplanned", svd_method="svd",
                bond_schedule=(8,), sweeps_per_bond=1, davidson_iters=2,
            )
