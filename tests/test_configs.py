"""Config sanity: every assigned arch resolves, param counts land in the
right ballpark (name vs. approximate count), shapes gate correctly."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, get_config

# (arch, expected params in billions, tolerance factor)
EXPECTED_B = {
    "llama3_8b": (8.0, 0.2),
    "codeqwen15_7b": (7.2, 0.25),
    "qwen15_110b": (111.0, 0.15),
    "granite_3_2b": (2.5, 0.3),
    "pixtral_12b": (12.0, 0.25),
    "qwen2_moe_a27b": (14.3, 0.3),
    # assignment pins 48L x 64e (HF Moonlight is 27L/16B); 48L gives ~29B
    "moonshot_v1_16b_a3b": (28.9, 0.15),
    "rwkv6_3b": (3.1, 0.4),
    "recurrentgemma_2b": (2.7, 0.4),
}


def test_all_archs_resolve():
    cfgs = all_configs()
    assert len(cfgs) == 10
    for a, c in cfgs.items():
        assert c.name == a
        assert c.d_model > 0 and c.n_layers > 0 and c.vocab_size > 0


@pytest.mark.parametrize("arch,exp", list(EXPECTED_B.items()))
def test_param_counts_ballpark(arch, exp):
    target, tol = exp
    n = get_config(arch).param_count() / 1e9
    assert abs(n - target) / target < tol, f"{arch}: {n:.2f}B vs {target}B"


def test_moe_active_counts():
    for arch, active_b in (("qwen2_moe_a27b", 2.7), ("moonshot_v1_16b_a3b", 4.8)):
        n = get_config(arch).active_param_count() / 1e9
        assert abs(n - active_b) / active_b < 0.5, f"{arch}: {n:.2f}B active"


def test_shape_gates():
    # long_500k only for sub-quadratic archs
    for a in ARCH_IDS:
        cfg = get_config(a)
        ok, why = cfg.shape_supported("long_500k")
        assert ok == cfg.sub_quadratic, (a, why)
        assert cfg.shape_supported("train_4k")[0]
    assert sum(get_config(a).sub_quadratic for a in ARCH_IDS) == 2


def test_smoke_configs_are_small():
    for a in ARCH_IDS:
        s = get_config(a).smoke()
        assert s.param_count() < 5e6, a
        assert s.d_model <= 64 and s.vocab_size <= 128


def test_40_cells_accounting():
    """10 archs x 4 shapes = 40 assigned cells; 32 run + 8 documented skips."""
    runnable = skipped = 0
    for a in ARCH_IDS:
        for sh in SHAPES:
            ok, why = get_config(a).shape_supported(sh)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert "sub-quadratic" in why
    assert runnable == 32 and skipped == 8
