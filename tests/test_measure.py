"""Observable measurement vs exact diagonalization."""
import numpy as np
import pytest

from repro.core import run_dmrg
from repro.core.ed import build_dense_hamiltonian, state_charges_vector
from repro.core.measure import correlation, site_expectation
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space


# DMRG-vs-ED observable comparisons: float64-only tolerances
pytestmark = pytest.mark.x64


@pytest.fixture(scope="module")
def ground_state():
    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
    n = 6
    res = run_dmrg(sp, terms, n, bond_schedule=(8, 16), sweeps_per_bond=2,
                   davidson_iters=6)
    # ED ground state in the Sz=0 sector for reference observables
    H = build_dense_hamiltonian(sp, terms, n)
    mask = np.all(state_charges_vector(sp, n) == np.array((0,)), axis=1)
    Hs = H[np.ix_(mask, mask)]
    w, v = np.linalg.eigh(Hs)
    full = np.zeros(2**n)
    full[mask] = v[:, 0]
    return sp, res.mps, full, n


def _ed_op(op, site, n, d=2):
    m = np.ones((1, 1))
    for s in range(n):
        m = np.kron(m, op if s == site else np.eye(d))
    return m


def test_sz_expectation_matches_ed(ground_state):
    sp, mps, psi, n = ground_state
    sz = np.asarray(sp.ops["Sz"])
    for site in (0, 2, 5):
        want = float(psi @ _ed_op(sz, site, n) @ psi)
        got = site_expectation(mps, sp, "Sz", site)
        np.testing.assert_allclose(got, want, atol=1e-7)


def test_szsz_correlation_matches_ed(ground_state):
    sp, mps, psi, n = ground_state
    sz = np.asarray(sp.ops["Sz"])
    for i, j in ((0, 1), (1, 4), (0, 5)):
        want = float(psi @ (_ed_op(sz, i, n) @ _ed_op(sz, j, n)) @ psi)
        got = correlation(mps, sp, "Sz", "Sz", i, j)
        np.testing.assert_allclose(got, want, atol=1e-7)


def test_spsm_correlation_matches_ed(ground_state):
    """Charged-operator string: S+_i S-_j (tests charged environments)."""
    sp, mps, psi, n = ground_state
    spo, smo = np.asarray(sp.ops["S+"]), np.asarray(sp.ops["S-"])
    for i, j in ((0, 3), (2, 5)):
        want = float(psi @ (_ed_op(spo, i, n) @ _ed_op(smo, j, n)) @ psi)
        got = correlation(mps, sp, "S+", "S-", i, j)
        np.testing.assert_allclose(got, want, atol=1e-7)
