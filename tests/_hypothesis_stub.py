"""Tiny deterministic fallback for ``hypothesis`` when it is not installed.

The container image does not ship hypothesis, and property tests crashing the
whole collection is worse than running them over a fixed deterministic sample
of each strategy.  This shim implements exactly the surface the test suite
uses — ``given``, ``settings``, ``strategies.integers``,
``strategies.sampled_from`` — running each ``@given`` test over up to
``max_examples`` (capped at 10) pseudo-random draws seeded per example index,
so failures are reproducible.  When real hypothesis is installed
(``pip install -r requirements-dev.txt``) conftest prefers it and this module
is never imported.
"""
from __future__ import annotations

import functools
import random
import sys
import types

_MAX_EXAMPLES_CAP = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(values):
    vals = list(values)
    return _Strategy(lambda rng: vals[rng.randrange(len(vals))])


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        if max_examples is not None:
            fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies_by_name):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit below @given (sets the attr on fn) or above
            # it (sets it on this wrapper); honor both orders like hypothesis
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", _MAX_EXAMPLES_CAP))
            n = min(n, _MAX_EXAMPLES_CAP)
            for i in range(n):
                rng = random.Random(0xD15C0 + 9973 * i)
                drawn = {k: s.draw(rng) for k, s in strategies_by_name.items()}
                fn(*args, **drawn, **kwargs)

        # pytest inspects signatures through __wrapped__ and would treat the
        # strategy parameters as fixtures; hide the original signature
        del wrapper.__dict__["__wrapped__"]
        return wrapper

    return deco


def install():
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
