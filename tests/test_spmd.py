"""True SPMD execution (dist/spmd.py): bucket-GEMM equality vs the
replicated reference, padding/fallback rules, compile-once program cache,
and full-DMRG energy equality vs the list backend at fake-device counts
{1, 2, 4, 8} (subprocess: the XLA device-count flag must precede jax)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import run_dmrg
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space
from repro.dist import BlockShardPolicy, make_block_mesh, spmd_stats
from repro.dist.engine import ContractionEngine
from repro.dist.spmd import (
    PAD_OVERHEAD_LIMIT,
    _ref_gemm,
    spmd_bucket_gemm,
)
from repro.tensor import contract

from test_dist import AX, rand_pair

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def rand_bucket(seed, p, m, k, n, num_out):
    rng = np.random.default_rng(seed)
    lhs = jnp.asarray(rng.standard_normal((p, m, k)))
    rhs = jnp.asarray(rng.standard_normal((p, k, n)))
    oi = jnp.asarray(rng.integers(0, num_out, size=p))
    return lhs, rhs, oi


class TestSpmdGemm:
    """In-process checks on the trivial (1, 1) mesh — the collective
    program must be exact even when the collectives are no-ops."""

    def test_matches_reference(self):
        mesh = make_block_mesh()
        for seed, (p, m, k, n, o) in enumerate(
            [(6, 4, 3, 5, 2), (1, 2, 2, 2, 1), (7, 8, 8, 8, 3)]
        ):
            lhs, rhs, oi = rand_bucket(seed, p, m, k, n, o)
            got = spmd_bucket_gemm(lhs, rhs, oi, o, mesh=mesh)
            want = _ref_gemm(lhs, rhs, oi, num_out=o)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=0, atol=1e-12)

    def test_fallback_on_pad_overhead(self):
        mesh = make_block_mesh()
        lhs, rhs, oi = rand_bucket(0, 3, 4, 4, 5, 2)
        before = spmd_stats()["fallback_calls"]
        got = spmd_bucket_gemm(lhs, rhs, oi, 2, mesh=mesh,
                               pad_overhead_limit=0.0)
        assert spmd_stats()["fallback_calls"] == before + 1
        want = _ref_gemm(lhs, rhs, oi, num_out=2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0, atol=1e-12)

    def test_program_cache_compile_once(self):
        mesh = make_block_mesh()
        lhs, rhs, oi = rand_bucket(3, 4, 4, 4, 4, 2)
        spmd_bucket_gemm(lhs, rhs, oi, 2, mesh=mesh)
        progs = spmd_stats()["unique_programs"]
        for seed in range(3):  # same shape, new values -> no new programs
            lhs, rhs, oi = rand_bucket(10 + seed, 4, 4, 4, 4, 2)
            spmd_bucket_gemm(lhs, rhs, oi, 2, mesh=mesh)
        assert spmd_stats()["unique_programs"] == progs


class TestSpmdEngine:
    def test_contraction_matches_list(self):
        policy = BlockShardPolicy(make_block_mesh(), mode="spmd")
        eng = ContractionEngine(policy=policy)
        for seed in range(4):
            A, B = rand_pair(seed)
            got = eng(policy.place(A), policy.place(B), AX)
            want = contract(A, B, AX)
            assert set(got.blocks) == set(want.blocks)
            for key in want.blocks:
                np.testing.assert_allclose(
                    np.asarray(got.blocks[key]), np.asarray(want.blocks[key]),
                    rtol=0, atol=1e-12)
        assert eng.stats()["backend_counts"]["spmd"] > 0

    def test_run_dmrg_spmd_matches_list_single_device(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=1, davidson_iters=4)
        single = run_dmrg(sp, terms, 6, algo="list", **kw)
        spmd = run_dmrg(sp, terms, 6, spmd=True, **kw)
        assert abs(single.energy - spmd.energy) < 1e-10

    def test_spmd_kwarg_rejects_storage_policy(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        storage = BlockShardPolicy(make_block_mesh())  # auto -> storage on CPU
        with pytest.raises(ValueError, match="spmd"):
            run_dmrg(sp, terms, 6, shard_policy=storage, spmd=True,
                     bond_schedule=(8,), sweeps_per_bond=1)

    def test_compile_once_across_sweeps(self):
        """The set of compiled SPMD programs stops growing once the block
        structures reach steady state (the retrace-free guarantee)."""
        from repro.core.mpo import build_mpo, compress_mpo
        from repro.core.mps import neel_states, product_state_mps
        from repro.core.sweep import DMRGEngine

        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        mpo = compress_mpo(build_mpo(sp, terms, 6), cutoff=1e-13)
        policy = BlockShardPolicy(make_block_mesh(), mode="spmd")
        eng = DMRGEngine(product_state_mps(sp, neel_states(sp, 6)), mpo,
                         davidson_iters=2, algo="batched", jit_matvec=True,
                         shard_policy=policy)
        for _ in range(4):
            eng.sweep(max_bond=8)
        progs = spmd_stats()["unique_programs"]
        retraces = eng.contract_fn.jit_retraces
        for _ in range(2):
            eng.sweep(max_bond=8)
        assert spmd_stats()["unique_programs"] == progs
        assert eng.contract_fn.jit_retraces == retraces


def _run_script(code, tmp_path, name, timeout=900):
    script = tmp_path / name
    script.write_text(code)
    proc = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


@pytest.mark.slow
class TestSpmdMultiDevice:
    """Real (non-trivial) meshes need fake devices, so each test runs in a
    subprocess that sets the XLA device-count flag before importing jax."""

    @pytest.mark.parametrize("ndev", [1, 2, 4, 8])
    def test_energy_matches_list(self, tmp_path, ndev):
        code = textwrap.dedent(f"""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        os.environ["JAX_ENABLE_X64"] = "1"
        import sys
        sys.path.insert(0, r"{SRC}")
        import jax
        assert jax.device_count() == {ndev}, jax.device_count()
        from repro.core import run_dmrg
        from repro.core.models import heisenberg_j1j2_terms
        from repro.core.siteops import spin_half_space

        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=1, davidson_iters=4)
        single = run_dmrg(sp, terms, 6, algo="list", **kw)
        spmd = run_dmrg(sp, terms, 6, spmd=True, **kw)
        diff = abs(single.energy - spmd.energy)
        assert diff < 1e-10, (single.energy, spmd.energy)
        print(f"SPMD_OK diff={{diff:.2e}}")
        """)
        out = _run_script(code, tmp_path, f"spmd_{ndev}dev.py")
        assert "SPMD_OK" in out

    def test_bucket_gemm_exact_on_2x4_mesh(self, tmp_path):
        """Block-for-block bucket-GEMM equality on a (2, 4) mesh, including
        pair/column counts NOT divisible by the mesh axes (padding path)."""
        code = textwrap.dedent(f"""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_ENABLE_X64"] = "1"
        import sys
        sys.path.insert(0, r"{SRC}")
        sys.path.insert(0, r"{os.path.dirname(os.path.abspath(__file__))}")
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.dist import BlockShardPolicy, make_block_mesh
        from repro.dist.engine import ContractionEngine
        from repro.dist.spmd import _ref_gemm, spmd_bucket_gemm
        from repro.tensor import contract
        from test_dist import AX, rand_pair

        mesh = make_block_mesh()
        assert (mesh.shape["row"], mesh.shape["col"]) == (2, 4), mesh.shape
        rng = np.random.default_rng(0)
        # (p, n) cases straddling the divisibility grid: p=3 pads to 4 rows'
        # worth, n=5 pads to 8 columns' worth, etc.
        for p, n in [(3, 5), (1, 1), (2, 4), (8, 8), (5, 7)]:
            m = k = 4
            o = max(1, p // 2)
            lhs = jnp.asarray(rng.standard_normal((p, m, k)))
            rhs = jnp.asarray(rng.standard_normal((p, k, n)))
            oi = jnp.asarray(rng.integers(0, o, size=p))
            got = spmd_bucket_gemm(lhs, rhs, oi, o, mesh=mesh,
                                   pad_overhead_limit=1e9)
            want = _ref_gemm(lhs, rhs, oi, num_out=o)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=0, atol=1e-12)
        # block-sparse contraction through the engine on the same mesh
        policy = BlockShardPolicy(mesh, mode="spmd")
        eng = ContractionEngine(policy=policy)
        for seed in range(3):
            A, B = rand_pair(seed)
            got = eng(policy.place(A), policy.place(B), AX)
            want = contract(A, B, AX)
            assert set(got.blocks) == set(want.blocks)
            for key in want.blocks:
                np.testing.assert_allclose(
                    np.asarray(got.blocks[key]),
                    np.asarray(want.blocks[key]), rtol=0, atol=1e-12)
        print("GEMM_2x4_OK")
        """)
        out = _run_script(code, tmp_path, "spmd_gemm_2x4.py")
        assert "GEMM_2x4_OK" in out
