import os
import sys

# 64-bit for DMRG numerics; LM-model code passes explicit float32/bfloat16
# dtypes, so this does not change the transformer stack's behavior.
os.environ.setdefault("JAX_ENABLE_X64", "1")
# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see the single real CPU device; only
# launch/dryrun.py (run as its own process) requests 512 placeholder devices.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests import hypothesis; the container may not ship it.  Fall back
# to the deterministic stub in _hypothesis_stub.py so collection never dies
# (real hypothesis, when installed via requirements-dev.txt, always wins).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()
