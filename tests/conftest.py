import os
import sys

import pytest

# 64-bit for DMRG numerics; LM-model code passes explicit float32/bfloat16
# dtypes, so this does not change the transformer stack's behavior.  CI also
# runs a float32 leg (JAX_ENABLE_X64=0 in the job env wins over this
# setdefault); tests whose tolerances genuinely need float64 carry the
# ``x64`` marker and are skipped there, so the f32 leg still exercises the
# whole precision-agnostic surface (dtype handling, plan caches, kernels).
os.environ.setdefault("JAX_ENABLE_X64", "1")
# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see the single real CPU device; only
# launch/dryrun.py (run as its own process) requests 512 placeholder devices.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests import hypothesis; the container may not ship it.  Fall back
# to the deterministic stub in _hypothesis_stub.py so collection never dies
# (real hypothesis, when installed via requirements-dev.txt, always wins).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop jit caches between test modules to bound the process's mmap count.

    Every compiled XLA executable holds several live mmaps and the default
    ``vm.max_map_count`` is 65530; a full-suite run accumulates enough
    compiled executables to cross that ceiling, at which point the NEXT
    compilation segfaults inside jaxlib (observed deterministically once the
    suite grew past ~200 tests: /proc/<pid>/maps hits ~65k right before the
    crash).  Clearing per module keeps each module's within-module caching
    behavior (retrace-counter tests warm up and assert inside one module)
    while releasing executables no later test can reach.

    Interaction with the persistent compilation cache (dist/persist.py):
    ``jax.clear_caches()`` drops only the *in-memory* trace/executable
    caches — the on-disk cache a ``PlanStore`` activation configured
    (``jax_compilation_cache_dir``) survives, by design, so post-clear
    re-compiles of already-seen programs are disk hits rather than full
    XLA compiles.  The disk entries hold no mmaps, so they don't count
    against ``vm.max_map_count``; only re-*loading* them does, and that is
    exactly the per-module budget this fixture resets.  The cache-dir
    config itself also survives (deliberately — unsetting it mid-process
    would orphan live executables' entries), which is why store-activating
    tests point it at per-test tmp dirs and why the teardown below detaches
    any store a test module leaked without touching the config.
    """
    yield
    import gc

    import jax

    # a leaked process-wide PlanStore would redirect every later module's
    # plan-cache misses into a (possibly deleted) tmp dir; detach it first
    from repro.dist import persist

    persist.deactivate_store()
    jax.clear_caches()
    gc.collect()


def pytest_collection_modifyitems(config, items):
    """Skip ``x64``-marked tests when jax runs in float32.

    The marker tags tests whose assertions are only meaningful at float64
    precision (1e-10 energy/block equality, ED comparisons, SVD round
    trips).  Asking jax itself (rather than re-parsing the env var, whose
    truthiness rules jax owns — e.g. "off" and "no" also disable x64)
    guarantees the skip decision matches the precision the suite runs with.
    """
    import jax

    if jax.config.jax_enable_x64:
        return
    skip = pytest.mark.skip(
        reason="needs float64 numerics (JAX_ENABLE_X64=1); f32 CI leg skips"
    )
    for item in items:
        if "x64" in item.keywords:
            item.add_marker(skip)
