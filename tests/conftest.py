import os
import sys

# 64-bit for DMRG numerics; LM-model code passes explicit float32/bfloat16
# dtypes, so this does not change the transformer stack's behavior.
os.environ.setdefault("JAX_ENABLE_X64", "1")
# NOTE: deliberately NOT setting --xla_force_host_platform_device_count here:
# smoke tests and benches must see the single real CPU device; only
# launch/dryrun.py (run as its own process) requests 512 placeholder devices.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
