"""Launch-layer tests: sharding resolution, cost parser, dry-run smoke on a
small in-process mesh (8 host devices via subprocess to avoid polluting the
test process's device count)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.launch.hlo_costs import total_costs

REPO = Path(__file__).resolve().parents[1]


class TestShardingRules:
    def _mesh(self):
        from repro.launch.mesh import make_mesh
        return make_mesh((1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        from jax.sharding import PartitionSpec as P
        from repro.launch.sharding import spec_for
        mesh = self._mesh()
        # everything divides a 1x1 mesh
        assert spec_for((60, 2048, 1408), ("expert", "embed", "expert_ff"),
                        mesh) == P("model", "data", None)

    def test_axis_used_once(self):
        from repro.launch.sharding import spec_for
        mesh = self._mesh()
        spec = spec_for((64, 64), ("heads", "ff"), mesh)
        used = [s for s in spec if s is not None]
        assert len(set(used)) == len(used)


class TestHloCosts:
    def test_while_trip_multiplication(self):
        hlo = textwrap.dedent("""\
        HloModule test
        %body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
          %dot.1 = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %c = s32[] constant(1)
          %i = s32[] get-tuple-element(%p), index=0
          %ip = s32[] add(%i, %c)
          ROOT %t = (s32[], f32[8,8]) tuple(%ip, %dot.1)
        }
        %cond.1 (p: (s32[], f32[8,8])) -> pred[] {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %n = s32[] constant(7)
          ROOT %lt = pred[] compare(%i, %n), direction=LT
        }
        ENTRY %main (x: f32[8,8]) -> f32[8,8] {
          %x = f32[8,8]{1,0} parameter(0)
          %z = s32[] constant(0)
          %t0 = (s32[], f32[8,8]) tuple(%z, %x)
          %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
          ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
        }
        """)
        t = total_costs(hlo)
        # dot flops = 2*8*8*8 = 1024, x 7 trips
        assert t["flops"] == pytest.approx(1024 * 7)

    def test_collective_wire_model(self):
        hlo = textwrap.dedent("""\
        ENTRY %main (x: f32[64]) -> f32[64] {
          %x = f32[64]{0} parameter(0)
          ROOT %ar = f32[64]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%sum
        }
        """)
        t = total_costs(hlo)
        # 2 * 256B * (4-1)/4 = 384
        assert t["coll"]["all-reduce"] == pytest.approx(384.0)


@pytest.mark.slow
class TestDryRunSmoke:
    """Full dry-run machinery on an 8-device host mesh (subprocess)."""

    def test_small_mesh_cell(self, tmp_path):
        code = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, r"%s")
        import jax, json
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = (
            lambda multi_pod=False: mesh_mod.make_mesh(
                (2, 2, 2) if multi_pod else (4, 2),
                ("pod", "data", "model") if multi_pod else ("data", "model"))
        )
        from repro.launch.dryrun import run_cell
        from pathlib import Path
        import dataclasses
        from repro.configs import base as cb
        cfg = cb.get_config("granite_3_2b").smoke()
        cfg = dataclasses.replace(cfg, name="granite_tiny")
        cb.register(cfg)
        for mp in (False, True):
            rec = run_cell("granite_tiny", "train_4k", mp, Path(r"%s"), force=True)
            assert rec["status"] == "ok", rec
            assert rec["flops_per_chip"] > 0
            assert rec["collective"]["total"] > 0
        print("SMOKE_OK")
        """) % (REPO / "src", tmp_path)
        # patch SHAPES to something tiny inside the subprocess
        code = code.replace(
            'from repro.launch.dryrun import run_cell',
            'import repro.configs.base as b;'
            'b.SHAPES["train_4k"] = dict(seq_len=64, global_batch=8, kind="train");'
            'from repro.launch.dryrun import run_cell')
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600)
        assert "SMOKE_OK" in r.stdout, r.stderr[-2000:]
