"""Training substrate: optimizer, data pipeline determinism, checkpointing
(atomicity + elastic restore), gradient compression, straggler detection."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.checkpoint import CheckpointManager
from repro.train.compress import compressed_grads, init_error_state
from repro.train.data import SyntheticLM
from repro.train.optim import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.straggler import StepMonitor


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.array([3.0, -2.0])}
        state = init_opt_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(oc, params, grads, state)
        assert float(jnp.sum(jnp.abs(params["w"]))) < 1e-2

    def test_grad_clip(self):
        oc = OptConfig(lr=1.0, grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        _, _, m = adamw_update(oc, params, {"w": jnp.full(4, 100.0)}, state)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_lr_schedule(self):
        oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(lr_at(oc, jnp.int32(5))) == pytest.approx(5e-4)
        assert float(lr_at(oc, jnp.int32(10))) == pytest.approx(1e-3)
        assert float(lr_at(oc, jnp.int32(100))) == pytest.approx(1e-4, rel=0.01)


class TestData:
    def test_deterministic_and_restorable(self):
        d1 = SyntheticLM(100, 32, 4, seed=7)
        b1 = [next(d1) for _ in range(3)]
        st_ = d1.state_dict()
        b_next = next(d1)
        d2 = SyntheticLM(100, 32, 4, seed=7)
        d2.load_state_dict(st_)
        b_resume = next(d2)
        np.testing.assert_array_equal(np.asarray(b_next["tokens"]),
                                      np.asarray(b_resume["tokens"]))
        # and different steps differ
        assert not np.array_equal(np.asarray(b1[0]["tokens"]),
                                  np.asarray(b1[1]["tokens"]))

    def test_labels_shifted(self):
        d = SyntheticLM(50, 16, 2, seed=1)
        b = next(d)
        assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3):
            cm.save(s, {"a": jnp.arange(4) * s}, meta={"s": s})
        assert cm.all_steps() == [2, 3]
        step, arrs, meta = cm.restore()
        assert step == 3 and meta["s"] == 3
        np.testing.assert_array_equal(np.asarray(arrs["a"]), np.arange(4) * 3)

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=3)
        cm.save_async(5, {"x": jnp.ones((8, 8))}, meta={})
        cm.wait()
        assert cm.latest_step() == 5

    def test_partial_write_ignored(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=3)
        cm.save(1, {"x": jnp.ones(2)})
        # simulate a crash mid-write: dir without manifest
        os.makedirs(tmp_path / "step_0000000002")
        (tmp_path / "step_0000000002" / "arrays.npz").write_bytes(b"junk")
        assert cm.latest_step() == 1

    def test_elastic_restore_resharding(self, tmp_path):
        """Restore onto a different mesh (1 device here, but via explicit
        sharding objects — the mesh-independence path)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        cm = CheckpointManager(tmp_path)
        cm.save(1, {"w": jnp.arange(16.0).reshape(4, 4)})
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))
        sh = NamedSharding(mesh, P("data", None))
        _, arrs, _ = cm.restore(shardings={"w": sh})
        assert arrs["w"].sharding == sh
        np.testing.assert_array_equal(
            np.asarray(arrs["w"]), np.arange(16.0).reshape(4, 4))


class TestCompression:
    @given(mode=st.sampled_from(["bf16", "int8"]), seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_error_feedback_bounds_error(self, mode, seed):
        """With error feedback, the ACCUMULATED applied gradient tracks the
        true accumulated gradient to quantization precision."""
        key = jax.random.PRNGKey(seed)
        params = {"w": jnp.zeros(64)}
        err = init_error_state(params)
        true_sum = jnp.zeros(64)
        applied_sum = jnp.zeros(64)
        for i in range(20):
            key, k2 = jax.random.split(key)
            g = {"w": jax.random.normal(k2, (64,))}
            true_sum = true_sum + g["w"]
            cg, err = compressed_grads(g, err, mode)
            applied_sum = applied_sum + cg["w"]
        # residual error is bounded by the final error-feedback state
        np.testing.assert_allclose(
            np.asarray(applied_sum + err["w"]), np.asarray(true_sum),
            rtol=1e-5, atol=1e-4,
        )

    def test_int8_single_step_error(self):
        g = {"w": jnp.linspace(-1, 1, 128)}
        cg, err = compressed_grads(g, init_error_state(g), "int8")
        assert float(jnp.max(jnp.abs(cg["w"] - g["w"]))) < 1.0 / 127 + 1e-6


class TestStraggler:
    def test_detects_spike(self):
        mon = StepMonitor(warmup=3, sigma_mult=3.0, evict_after=2)
        for i in range(10):
            mon.stop(i, seconds=0.1)
        r = mon.stop(10, seconds=1.0)
        assert r is not None and not r.evict
        r = mon.stop(11, seconds=1.0)
        assert r is not None and r.evict

    def test_tolerates_noise(self):
        mon = StepMonitor(warmup=3)
        rng = np.random.default_rng(0)
        reports = [mon.stop(i, seconds=0.1 + 0.005 * rng.random())
                   for i in range(50)]
        assert all(r is None for r in reports)


def test_train_loop_end_to_end(tmp_path):
    """Tiny real training run: loss must drop; resume must continue."""
    from repro.launch.train import main

    common = ["--arch", "granite_3_2b", "--smoke",
              "--global-batch", "2", "--seq-len", "32", "--log-every", "0",
              "--checkpoint-every", "6", "--checkpoint-dir", str(tmp_path)]
    losses = main(common + ["--steps", "12"])
    assert losses[-1] < losses[0]
    losses2 = main(common + ["--steps", "16", "--resume", "auto"])
    assert len(losses2) == 4  # resumed at 12, ran to 16
