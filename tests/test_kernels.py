"""Per-kernel validation: Pallas interpret mode vs pure-jnp oracles,
with hypothesis sweeps over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.block_gemm.kernel import block_sparse_matmul as bg_kernel
from repro.kernels.block_gemm.ops import block_sparse_matmul as bg_op
from repro.kernels.block_gemm.ref import block_sparse_matmul_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan


class TestBlockGemm:
    @given(
        p=st.integers(1, 6),
        o=st.integers(1, 3),
        bm=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([16, 32]),
        bn=st.sampled_from([16, 32]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_ref(self, p, o, bm, bk, bn, dtype, seed):
        o = min(o, p)
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        dt = jnp.dtype(dtype)
        lhs = jax.random.normal(k1, (p, bm, bk), jnp.float32).astype(dt)
        rhs = jax.random.normal(k2, (p, bk, bn), jnp.float32).astype(dt)
        out_idx = jnp.sort(
            jnp.concatenate([jnp.arange(o),
                             jax.random.randint(k3, (p - o,), 0, o)])
        ).astype(jnp.int32)
        got = bg_op(lhs, rhs, out_idx, o, bm=16, bn=128, bk=128, interpret=True)
        want = block_sparse_matmul_ref(lhs, rhs, out_idx, o)
        tol = 1e-5 if dtype == "float32" else 5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_k_tiling_accumulation(self):
        """BK > tile: the kernel must accumulate across k-steps."""
        key = jax.random.PRNGKey(0)
        lhs = jax.random.normal(key, (3, 16, 512), jnp.float32)
        rhs = jax.random.normal(key, (3, 512, 128), jnp.float32)
        idx = jnp.array([0, 0, 1], jnp.int32)
        got = bg_kernel(lhs, rhs, idx, 2, bm=16, bn=128, bk=128, interpret=True)
        want = block_sparse_matmul_ref(lhs, rhs, idx, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @given(
        s=st.sampled_from([64, 128, 256]),
        d=st.sampled_from([32, 64, 128]),
        bh=st.integers(1, 4),
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 50),
    )
    @settings(max_examples=10, deadline=None)
    def test_matches_ref(self, s, d, bh, dtype, seed):
        key = jax.random.PRNGKey(seed)
        dt = jnp.dtype(dtype)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (bh, s, d), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (bh, s, d), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (bh, s, d), jnp.float32).astype(dt)
        got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
        want = flash_attention_ref(q, k, v)
        tol = 2e-5 if dtype == "float32" else 3e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_bshd_gqa_wrapper(self):
        """GQA layout + head-dim padding path vs the model's attention."""
        from repro.models.attention import causal_attention

        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 3)
        b, s, h, hkv, d = 2, 128, 8, 2, 48  # d=48 forces lane padding
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
        got = flash_attention_bshd(q, k, v, bq=64, bk=64, interpret=True)
        want = causal_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_long_causality(self):
        """Future keys must not affect output (strict causality)."""
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (1, 128, 64), jnp.float32)
        k = jax.random.normal(key, (1, 128, 64), jnp.float32)
        v = jax.random.normal(key, (1, 128, 64), jnp.float32)
        o1 = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
        k2 = k.at[:, 64:].set(99.0)
        v2 = v.at[:, 64:].set(-99.0)
        o2 = flash_attention(q, k2, v2, bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(o1[:, :64]),
                                   np.asarray(o2[:, :64]), rtol=1e-6)


class TestRwkv6Scan:
    def _inputs(self, bh, t, n, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = jax.random.normal(ks[0], (bh, t, n), jnp.float32) * 0.5
        k = jax.random.normal(ks[1], (bh, t, n), jnp.float32) * 0.5
        v = jax.random.normal(ks[2], (bh, t, n), jnp.float32)
        logw = -jnp.exp(jax.random.normal(ks[3], (bh, t, n)) * 0.5)
        u = jax.random.normal(ks[4], (bh, n), jnp.float32) * 0.1
        return r, k, v, logw, u

    def _ref(self, r, k, v, logw, u):
        """Naive O(T) recurrence oracle."""
        bh, t, n = r.shape
        s = jnp.zeros((bh, n, n))
        outs = []
        for i in range(t):
            kv = jnp.einsum("bn,bm->bnm", k[:, i], v[:, i])
            outs.append(jnp.einsum("bn,bnm->bm", r[:, i],
                                   s + u[:, :, None] * kv))
            s = s * jnp.exp(logw[:, i])[:, :, None] + kv
        return jnp.stack(outs, axis=1)

    @given(t=st.sampled_from([16, 32, 64]), chunk=st.sampled_from([8, 16, 32]),
           seed=st.integers(0, 30))
    @settings(max_examples=8, deadline=None)
    def test_matches_recurrence(self, t, chunk, seed):
        r, k, v, logw, u = self._inputs(2, t, 16, seed)
        got = rwkv6_scan(r, k, v, logw, u, chunk=chunk, interpret=True)
        want = self._ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_model_chunked(self):
        """Kernel == the model's jnp chunked path (same algorithm)."""
        from repro.models import rwkv6 as rk

        bh, t, n = 4, 64, 16
        r, k, v, logw, u = self._inputs(bh, t, n, seed=3)
        got = rwkv6_scan(r, k, v, logw, u, chunk=16, interpret=True)
        want = self._ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
