"""int8 KV-cache quantization: decode stays close to the bf16-cache path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config


def test_int8_cache_decode_close_to_fp():
    cfg = get_config("llama3_8b").smoke()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params, _ = models.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size, jnp.int32)
    c_fp = models.init_cache(cfg, b, s)
    c_q = models.init_cache(cfg8, b, s)
    assert c_q["blocks/L0/k"].dtype == jnp.int8
    outs_fp, outs_q = [], []
    for t in range(s):
        lf, c_fp = models.decode_step(cfg, params, c_fp, tokens[:, t],
                                      jnp.int32(t))
        lq, c_q = models.decode_step(cfg8, params, c_q, tokens[:, t],
                                     jnp.int32(t))
        outs_fp.append(lf)
        outs_q.append(lq)
    fp = np.asarray(jnp.stack(outs_fp))
    q = np.asarray(jnp.stack(outs_q))
    # greedy decisions nearly identical (random-init logits are near-uniform,
    # so an occasional near-tie may flip); logits within quantization noise
    agree = np.mean(fp.argmax(-1) == q.argmax(-1))
    assert agree >= 0.9, agree
    assert np.max(np.abs(fp - q)) < 0.15 * np.max(np.abs(fp))


def test_int8_cache_bytes_halved():
    cfg8 = dataclasses.replace(get_config("llama3_8b").smoke(),
                               kv_cache_dtype="int8")
    cfg = get_config("llama3_8b").smoke()
    def cache_bytes(c):
        return sum(v.size * v.dtype.itemsize for v in c.values())
    b8 = cache_bytes(models.init_cache(cfg8, 4, 256))
    bf = cache_bytes(models.init_cache(cfg, 4, 256))
    assert b8 < 0.6 * bf  # int8 + scales ~ 0.53x of f32 smoke cache
