"""Persistent plan + executable store (dist/persist.py): signature
canonicalization, plan roundtrips through the LRU caches (zero rebuilds,
bit-identical engine outputs), version/corruption gating, jax.export
roundtrips + custom_call refusal tombstones, prefetch warm-up, activation
scoping — and the cross-process cold-start contract (prime in process A,
process B's first sweep sees zero plan builds and a >=5x speedup)."""
import json
import os
import pickle
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import run_dmrg
from repro.core.ed import ground_energy
from repro.core.mps import neel_states, total_charge
from repro.dist import ContractionEngine, PlanCache, persist
from repro.dist.persist import (
    PERSIST_VERSION,
    PlanStore,
    canonical_signature,
    signature_digest,
)
from repro.dist.plan import (
    global_decomp_cache,
    global_env_cache,
    global_plan_cache,
    plan_signature,
)
from repro.serve.problems import MODEL_BUILDERS
from repro.tensor import OUT, BlockSparseTensor, Index

AX = ((1,), (0,))


def rand_index(rng, nq=1, max_sectors=3, max_dim=4, flow=OUT):
    ns = rng.integers(1, max_sectors + 1)
    charges = rng.choice(np.arange(-2, 3), size=(8, nq), replace=True)
    charges = [tuple(int(c) for c in q) for q in charges]
    uniq = []
    for q in charges:
        if q not in uniq:
            uniq.append(q)
    uniq = uniq[:ns]
    return Index(
        tuple((q, int(rng.integers(1, max_dim + 1))) for q in uniq), flow
    )


def rand_pair(seed, nq=1):
    rng = np.random.default_rng(seed)
    shared = rand_index(rng, nq=nq)
    ia = rand_index(rng, nq=nq)
    ib = rand_index(rng, nq=nq)
    A = BlockSparseTensor.random([ia, shared], key=jax.random.PRNGKey(seed))
    B = BlockSparseTensor.random(
        [shared.dual(), ib], key=jax.random.PRNGKey(seed + 1)
    )
    return A, B

BENCH = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "bench_dist.py"
)


def _coldstart_child(store_dir, phase, timeout=900):
    """Run one bench_dist cold-start child (its own process) and parse it."""
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(BENCH), "--child-coldstart",
         str(store_dir), phase],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_COLDSTART_JSON "):
            return json.loads(line[len("BENCH_COLDSTART_JSON "):])
    raise AssertionError(proc.stdout)


class TestSignatures:
    def test_digest_ignores_index_names(self):
        A, B = rand_pair(3)
        renamed = BlockSparseTensor(
            tuple(Index(ix.sectors, ix.flow, "other") for ix in A.indices),
            A.blocks,
            A.charge,
        )
        assert signature_digest(plan_signature(A, B, AX)) == signature_digest(
            plan_signature(renamed, B, AX)
        )

    def test_digest_distinguishes_structure(self):
        A, B = rand_pair(0)
        C, D = rand_pair(5)
        if plan_signature(A, B, AX) == plan_signature(C, D, AX):
            pytest.skip("random structures collided")
        assert signature_digest(plan_signature(A, B, AX)) != signature_digest(
            plan_signature(C, D, AX)
        )

    def test_canonical_form_drops_names_only(self):
        ix = Index((((0,), 2), ((1,), 3)), 1, "named")
        canon = canonical_signature((ix, 7, "s"))
        assert canon == (("Ix", ix.sectors, ix.flow), 7, "s")


class TestPlanRoundtrip:
    def test_primed_cache_zero_builds_bit_identical(self, tmp_path):
        """A second cache on the same store loads instead of building, and
        the engine's outputs through the loaded plan are bit-identical."""
        A, B = rand_pair(1)
        store = PlanStore(tmp_path)
        cache = PlanCache()
        cache.store = store
        eng = ContractionEngine(backend="list", cache=cache)
        C1 = eng(A, B, AX)
        assert cache.builds == 1
        assert store.stats()["saves"] == 1

        cache2 = PlanCache()
        cache2.store = store
        eng2 = ContractionEngine(backend="list", cache=cache2)
        C2 = eng2(A, B, AX)
        assert cache2.builds == 0, "primed store must satisfy the miss"
        assert store.stats()["hits"] == 1
        assert set(C1.blocks) == set(C2.blocks)
        for k in C1.blocks:
            # same plan content -> same pair order -> identical accumulation
            np.testing.assert_array_equal(
                np.asarray(C1.blocks[k]), np.asarray(C2.blocks[k])
            )

    def test_version_mismatch_rejected_and_repaired(self, tmp_path):
        A, B = rand_pair(2)
        sig = plan_signature(A, B, AX)
        store = PlanStore(tmp_path)
        cache = PlanCache()
        cache.store = store
        cache.get(A, B, AX)
        path = store._plan_path("contraction", sig)
        with open(path, "rb") as f:
            entry = pickle.load(f)
        entry["version"] = PERSIST_VERSION + 1
        with open(path, "wb") as f:
            pickle.dump(entry, f)

        store2 = PlanStore(tmp_path)
        assert store2.load_plan("contraction", sig) is None
        assert store2.stats()["stale"] == 1
        # a cache on the stale store rebuilds and repairs the entry
        cache2 = PlanCache()
        cache2.store = store2
        cache2.get(A, B, AX)
        assert cache2.builds == 1
        store3 = PlanStore(tmp_path)
        assert store3.load_plan("contraction", sig) is not None
        assert store3.stats() ["hits"] == 1

    @pytest.mark.parametrize("payload", [b"", b"garbage", b"\x80\x04X"])
    def test_corrupt_entry_is_a_counted_miss(self, tmp_path, payload):
        A, B = rand_pair(4)
        sig = plan_signature(A, B, AX)
        store = PlanStore(tmp_path)
        path = store._plan_path("contraction", sig)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "wb") as f:
            f.write(payload)
        assert store.load_plan("contraction", sig) is None
        assert store.stats()["corrupt"] == 1

    def test_truncated_entry_rebuilt(self, tmp_path):
        """A torn write (simulated by truncation) never crashes a load; the
        next build atomically repairs the entry."""
        A, B = rand_pair(6)
        sig = plan_signature(A, B, AX)
        store = PlanStore(tmp_path)
        cache = PlanCache()
        cache.store = store
        cache.get(A, B, AX)
        path = store._plan_path("contraction", sig)
        with open(path, "rb") as f:
            data = f.read()
        with open(path, "wb") as f:
            f.write(data[: len(data) // 2])

        store2 = PlanStore(tmp_path)
        cache2 = PlanCache()
        cache2.store = store2
        cache2.get(A, B, AX)
        assert store2.stats()["corrupt"] == 1
        assert cache2.builds == 1
        assert store2.stats()["saves"] == 1  # repaired
        store3 = PlanStore(tmp_path)
        assert store3.load_plan("contraction", sig) is not None

    def test_foreign_kind_rejected(self, tmp_path):
        """An entry pickled under one kind never aliases another kind's
        lookup, even at an identical digest."""
        A, B = rand_pair(7)
        sig = plan_signature(A, B, AX)
        store = PlanStore(tmp_path)
        cache = PlanCache()
        cache.store = store
        cache.get(A, B, AX)
        src = store._plan_path("contraction", sig)
        dst = store._plan_path("decomp", sig)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        with open(src, "rb") as f:
            data = f.read()
        with open(dst, "wb") as f:
            f.write(data)
        assert store.load_plan("decomp", sig) is None
        assert store.stats()["corrupt"] == 1


class TestExports:
    def _arr(self, shape=(4, 4)):
        return jnp.arange(
            np.prod(shape), dtype=jnp.float64 if jax.config.jax_enable_x64
            else jnp.float32
        ).reshape(shape)

    def test_export_roundtrip_across_store_instances(self, tmp_path):
        x = self._arr()
        fn = lambda a: a @ a.T  # pure-XLA program, exportable

        store = PlanStore(tmp_path)
        assert store.save_export(("core", "k1"), fn, (x,))
        assert store.stats()["export_saves"] == 1

        fresh = PlanStore(tmp_path)  # empty memo: must go through disk
        loaded = fresh.load_export(("core", "k1"), (x,))
        assert loaded is not None
        assert fresh.stats()["export_hits"] == 1
        np.testing.assert_allclose(
            np.asarray(loaded(x)), np.asarray(fn(x)), atol=0
        )

    def test_export_aval_mismatch_is_a_miss(self, tmp_path):
        x = self._arr((4, 4))
        store = PlanStore(tmp_path)
        assert store.save_export(("core", "k1"), lambda a: a * 2, (x,))
        y = self._arr((8, 8))
        assert store.load_export(("core", "k1"), (y,)) is None
        assert store.stats()["export_misses"] == 1

    @pytest.mark.x64
    def test_custom_call_refused_with_tombstone(self, tmp_path):
        """LAPACK-lowered programs are refused (they do not survive a
        cross-process deserialize), a tombstone is written, and every later
        save attempt is skipped without re-exporting."""
        x = self._arr((6, 4))
        svals = lambda a: jnp.linalg.svd(a, full_matrices=False)[1]

        store = PlanStore(tmp_path)
        assert not store.save_export(("svd", "k"), svals, (x,))
        assert store.stats()["export_failures"] == 1
        names = os.listdir(os.path.join(store.root, "exports"))
        assert len(names) == 1
        with open(os.path.join(store.root, "exports", names[0]), "rb") as f:
            entry = pickle.load(f)
        assert entry["refused"] == "custom_call"
        assert "data" not in entry

        # a fresh process (instance) reads the tombstone: load is a miss,
        # save is refused without paying export + module scan again
        fresh = PlanStore(tmp_path)
        assert fresh.load_export(("svd", "k"), (x,)) is None
        assert fresh.stats()["export_misses"] == 1
        assert not fresh.save_export(("svd", "k"), svals, (x,))
        assert fresh.stats()["export_failures"] == 1

    def test_prefetch_warms_the_memo(self, tmp_path):
        x = self._arr()
        store = PlanStore(tmp_path)
        store.save_export(("core", "a"), lambda a: a + 1, (x,))
        store.save_export(("core", "b"), lambda a: a - 1, (x,))

        fresh = PlanStore(tmp_path)
        assert fresh.prefetch_exports(block=True) == 2
        assert fresh.stats()["export_prefetched"] == 2
        # both lookups resolve from the warmed memo
        fa = fresh.load_export(("core", "a"), (x,))
        fb = fresh.load_export(("core", "b"), (x,))
        assert fa is not None and fb is not None
        assert fresh.stats()["export_hits"] == 2
        np.testing.assert_allclose(np.asarray(fa(x)), np.asarray(x + 1))
        # re-prefetch schedules nothing (everything already memoized)
        assert fresh.prefetch_exports(block=True) == 0

    def test_corrupt_export_is_a_counted_miss(self, tmp_path):
        x = self._arr()
        store = PlanStore(tmp_path)
        store.save_export(("core", "a"), lambda a: a + 1, (x,))
        d = os.path.join(store.root, "exports")
        name = os.listdir(d)[0]
        with open(os.path.join(d, name), "wb") as f:
            f.write(b"torn")
        fresh = PlanStore(tmp_path)
        assert fresh.load_export(("core", "a"), (x,)) is None
        assert fresh.stats()["export_corrupt"] == 1


class TestActivation:
    def test_using_store_scopes_and_restores(self, tmp_path):
        assert persist.active_store() is None
        with persist.using_store(str(tmp_path), prefetch=False) as s1:
            assert persist.active_store() is s1
            inner = tmp_path / "inner"
            with persist.using_store(str(inner), prefetch=False) as s2:
                assert persist.active_store() is s2
            assert persist.active_store() is s1
        assert persist.active_store() is None

    def test_run_dmrg_plan_store_detaches_after_run(self, tmp_path):
        space, terms = MODEL_BUILDERS["heisenberg"](4)
        res = run_dmrg(space, terms, 4, bond_schedule=(8,),
                       sweeps_per_bond=1, davidson_iters=2, algo="list",
                       plan_store=str(tmp_path))
        assert persist.active_store() is None
        assert res.energy < 0
        store = PlanStore(tmp_path)
        assert os.path.isdir(os.path.join(store.root, "contraction"))


def _clear_global_caches():
    global_plan_cache.clear()
    global_decomp_cache.clear()
    global_env_cache.clear()


@pytest.mark.x64
class TestPrimedEqualsCold:
    """The store must be physics-transparent: a run against a primed store
    (all plans loaded, zero builds) lands on the cold run's energies."""

    @settings(max_examples=5, deadline=None)
    @given(j2=st.floats(0.0, 1.0), n=st.sampled_from([4, 6]))
    def test_primed_equals_cold_energy(self, j2, n):
        space, terms = MODEL_BUILDERS["j1j2_ladder"](n, J1=1.0, J2=j2)
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4,
                  algo="list")
        with tempfile.TemporaryDirectory(prefix="persist_prop_") as d:
            _clear_global_caches()
            cold = run_dmrg(space, terms, n, plan_store=d, **kw)
            # drop the in-memory caches: the primed run must come out of
            # the store, not out of this process's memory
            _clear_global_caches()
            primed = run_dmrg(space, terms, n, plan_store=d, **kw)
            builds = (global_plan_cache.builds + global_decomp_cache.builds
                      + global_env_cache.builds)
        _clear_global_caches()
        assert builds == 0, "primed store must satisfy every plan miss"
        assert abs(cold.energy - primed.energy) < 1e-10
        for s_cold, s_primed in zip(cold.sweep_stats, primed.sweep_stats):
            assert abs(s_cold.energy - s_primed.energy) < 1e-10


@pytest.mark.x64
class TestEDCrossCheck:
    """run_dmrg (with a plan store active, exercising the full persistence
    path) matches exact diagonalization at L=8 for both registered serve
    models — the end-to-end correctness net under the cold-start machinery."""

    @pytest.mark.parametrize("model", sorted(MODEL_BUILDERS))
    def test_ground_energy_matches_ed_l8(self, model, tmp_path):
        n = 8
        space, terms = MODEL_BUILDERS[model](n)
        q = total_charge(space, neel_states(space, n))
        e0 = ground_energy(space, terms, n, charge=q)
        res = run_dmrg(space, terms, n, bond_schedule=(8, 16, 32),
                       sweeps_per_bond=2, davidson_iters=6,
                       plan_store=str(tmp_path))
        assert abs(res.energy - e0) < 1e-8, (model, res.energy, e0)


@pytest.mark.slow
class TestConcurrentAccess:
    """Two processes hammering the same store concurrently: atomic writes
    mean readers never observe a torn entry and both writers succeed."""

    def test_two_process_store_access(self, tmp_path):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        code = textwrap.dedent(f"""\
        import sys
        sys.path.insert(0, r"{os.path.abspath(src)}")
        from repro.dist.persist import PlanStore

        store = PlanStore(sys.argv[1])
        seed = int(sys.argv[2])
        # all workers write the SAME signatures (maximal path contention)
        # with worker-distinct payloads: any winner is complete
        for rounds in range(20):
            for i in range(10):
                sig = ("shared", i)
                payload = ("plan-payload", seed, rounds, i, "x" * 4096)
                assert store.save_plan("contraction", sig, payload)
                got = store.load_plan("contraction", sig)
                # the other worker may have won the race, but the entry
                # must always be complete and well-formed
                assert got is not None and got[0] == "plan-payload", got
        st = store.stats()
        assert st["corrupt"] == 0 and st["stale"] == 0, st
        print("WORKER_OK", st["saves"], st["hits"])
        """)
        script = tmp_path / "store_worker.py"
        script.write_text(code)
        store_dir = tmp_path / "store"
        procs = [
            subprocess.Popen(
                [sys.executable, str(script), str(store_dir), str(seed)],
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            )
            for seed in (1, 2)
        ]
        for p in procs:
            out, err = p.communicate(timeout=300)
            assert p.returncode == 0, err[-2000:]
            assert "WORKER_OK" in out
        # afterwards every entry is readable by a fresh store
        reader = PlanStore(store_dir)
        for i in range(10):
            assert reader.load_plan("contraction", ("shared", i)) is not None
        st = reader.stats()
        assert st["corrupt"] == 0 and st["hits"] == 10, st


@pytest.mark.slow
@pytest.mark.x64
class TestColdStartRegression:
    """The cold-start contract, measured across a real process boundary:
    process A primes the store (and runs the warmup compile pass); process
    B's first sweep then builds ZERO plans, reproduces A's energy to 1e-10
    and runs >=5x faster than A's cold first sweep (measured ~10x; the
    margin absorbs machine noise)."""

    def test_primed_process_zero_builds_and_speedup(self, tmp_path):
        cold = _coldstart_child(tmp_path, "cold")
        primed = _coldstart_child(tmp_path, "primed")
        assert primed["plan_builds"] == 0, primed
        assert abs(cold["energy"] - primed["energy"]) < 1e-10
        assert cold["store"]["saves"] > 0 and cold["store"]["export_saves"] > 0
        assert primed["store"]["hits"] > 0
        speedup = cold["first_s"] / max(primed["first_s"], 1e-9)
        assert speedup >= 5.0, (
            f"primed first sweep only {speedup:.1f}x faster than cold "
            f"({cold['first_s']:.2f}s -> {primed['first_s']:.2f}s)"
        )
