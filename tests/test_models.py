"""Per-arch smoke tests (reduced configs) + train/decode consistency.

Every assigned architecture: instantiate the reduced config, run one forward
and one gradient step on CPU, assert output shapes and finiteness.  Decode
consistency checks that the cache path reproduces teacher-forced logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCH_IDS, get_config


def make_batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_grad(self, arch):
        cfg = get_config(arch).smoke()
        params, axes = models.init(cfg, jax.random.PRNGKey(0))
        assert set(axes) == set(params)
        for k, v in params.items():
            assert len(axes[k]) == v.ndim, k
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        logits = models.forward(cfg, params, batch)
        b, s = batch["tokens"].shape
        s_total = s + (cfg.n_patches if cfg.family == "vlm" else 0)
        assert logits.shape[:2] == (b, s_total)
        assert bool(jnp.all(jnp.isfinite(logits)))
        loss, grads = jax.value_and_grad(
            lambda p: models.loss_fn(cfg, p, batch)
        )(params)
        assert bool(jnp.isfinite(loss))
        gsum = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
        assert np.isfinite(gsum) and gsum > 0

    def test_decode_shapes(self, arch):
        cfg = get_config(arch).smoke()
        params, _ = models.init(cfg, jax.random.PRNGKey(0))
        b = 2
        cache = models.init_cache(cfg, b, 64)
        tok = jnp.ones((b,), jnp.int32)
        if cfg.family == "audio":
            from repro.models.whisper import whisper_prime_cache
            enc = jax.random.normal(
                jax.random.PRNGKey(2), (b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
            cache = whisper_prime_cache(cfg, params, cache, enc)
        logits, cache2 = models.decode_step(cfg, params, cache, tok, jnp.int32(0))
        from repro.models.lm import padded_vocab
        assert logits.shape == (b, padded_vocab(cfg))
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert set(cache2) == set(cache)


@pytest.mark.parametrize("arch", ["llama3_8b", "rwkv6_3b", "recurrentgemma_2b",
                                  "qwen2_moe_a27b"])
def test_decode_matches_teacher_forcing(arch):
    """Sequential cached decode must reproduce full-sequence forward logits."""
    cfg = get_config(arch).smoke()
    params, _ = models.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size,
                                jnp.int32)
    full = models.forward(cfg, params, {"tokens": tokens})  # [B,S,V]
    cache = models.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        logits, cache = models.decode_step(cfg, params, cache, tokens[:, t],
                                           jnp.int32(t))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3
    )


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper_tiny").smoke()
    params, _ = models.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    enc = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.enc_seq_len, cfg.d_model),
                            jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size,
                                jnp.int32)
    full = models.forward(cfg, params, {"enc_embeds": enc, "tokens": tokens})
    from repro.models.whisper import whisper_prime_cache
    cache = models.init_cache(cfg, b, s)
    cache = whisper_prime_cache(cfg, params, cache, enc)
    outs = []
    for t in range(s):
        logits, cache = models.decode_step(cfg, params, cache, tokens[:, t],
                                           jnp.int32(t))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_moe_sorted_matches_dense_dispatch():
    """sorted (sparse-sparse analogue) == dense (sparse-dense analogue)."""
    from repro.models.moe import moe_ffn

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    t, d, e, f, k = 64, 16, 8, 32, 2
    x = jax.random.normal(ks[0], (2, t // 2, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, e)) * 0.1
    wg = jax.random.normal(ks[2], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (e, f, d)) * 0.1
    y_sorted = moe_ffn(x, wr, wg, wu, wd, top_k=k, capacity_factor=8.0)
    y_dense = moe_ffn(x, wr, wg, wu, wd, top_k=k, dispatch="dense")
    np.testing.assert_allclose(
        np.asarray(y_sorted), np.asarray(y_dense), rtol=1e-4, atol=1e-5
    )


def test_rwkv_chunked_matches_stepwise():
    """Chunked linear-attention form == naive O(T) recurrence."""
    from repro.models import rwkv6 as rk
    from repro.models.common import Registry

    d, h, n = 32, 4, 8
    reg = Registry(jax.random.PRNGKey(0))
    rk.time_mix_params(reg, "tm", d, h, n, lora=8)
    p = {k[3:]: v for k, v in reg.params.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, d), jnp.float32) * 0.5
    out_chunk, (s_fin, _) = rk.time_mix(p, x, h, n, chunk=8)
    # stepwise
    s = jnp.zeros((2, h, n, n), jnp.float32)
    x_last = jnp.zeros((2, d), jnp.float32)
    outs = []
    for t in range(20):
        o, (s, x_last) = rk.time_mix_decode(p, x[:, t : t + 1], s, x_last, h, n)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_chunk), np.asarray(out_step), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s), rtol=1e-4, atol=1e-5)
