"""Environment engine: the fused plan-cached env updates of dist/envcore.py
vs the seed extend_left/extend_right, compile-once retrace accounting, plan
cache semantics, and the sweep/dmrg ``jit_env`` knob."""
import jax
import numpy as np
import pytest

from repro.core import run_dmrg
from repro.core.env import extend_left, extend_right, left_edge, right_edge
from repro.core.models import heisenberg_j1j2_terms
from repro.core.mpo import build_mpo, compress_mpo
from repro.core.mps import neel_states, product_state_mps
from repro.core.siteops import spin_half_space
from repro.core.sweep import DMRGEngine
from repro.dist import EnvironmentEngine, EnvPlanCache
from repro.dist.envcore import env_out_indices
from repro.tensor.blocksparse import contract

# block-for-block equality bound: the fused core runs the same pair tables
# in the same order, so the only slack is padded-space accumulation noise
TOL = 1e-10 if jax.config.jax_enable_x64 else 2e-4


def _converged_system(n=6, m=8, sweeps=2, algo="list"):
    sp = spin_half_space()
    terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
    mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)
    mps = product_state_mps(sp, neel_states(sp, n))
    eng = DMRGEngine(mps, mpo, davidson_iters=2, algo=algo, jit_env=False)
    for _ in range(sweeps):
        eng.sweep(max_bond=m)
    return eng


def _assert_env_equal(got, ref, tol=TOL):
    assert got.indices == ref.indices
    assert got.charge == ref.charge
    assert set(got.blocks) == set(ref.blocks)
    for k in ref.blocks:
        np.testing.assert_allclose(
            np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=tol
        )


class TestFusedEqualsSeed:
    """Planned fused updates == seed extend_left/extend_right block-for-block
    across all engine backends (the fused core is backend-independent; the
    parametrization exercises the ContractionEngine threading)."""

    @pytest.mark.parametrize(
        "backend", ["list", "dense", "batched", "csr_ref", "auto"]
    )
    def test_left_and_right_passes(self, backend):
        n = 6
        eng = _converged_system(n=n, algo=backend)
        T, W = eng.mps.tensors, eng.mpo
        ceng = eng.contract_fn

        A_ref = A_got = left_edge(T[0], W[0])
        for j in range(n - 1):
            A_ref = extend_left(A_ref, T[j], W[j], contract)
            A_got = ceng.env_update_left(A_got, T[j], W[j])
            _assert_env_equal(A_got, A_ref)

        B_ref = B_got = right_edge(T[n - 1], W[n - 1])
        for j in range(n - 1, 0, -1):
            B_ref = extend_right(B_ref, T[j], W[j], contract)
            B_got = ceng.env_update_right(B_got, T[j], W[j])
            _assert_env_equal(B_got, B_ref)

    def test_unpadded_core_matches_too(self):
        """pad=False runs the same fused body on the raw structures."""
        n = 6
        eng = _converged_system(n=n)
        T, W = eng.mps.tensors, eng.mpo
        ee = EnvironmentEngine(cache=EnvPlanCache(), pad=False)
        A_ref = A_got = left_edge(T[0], W[0])
        for j in range(n - 1):
            A_ref = extend_left(A_ref, T[j], W[j], contract)
            A_got = ee.update_left(A_got, T[j], W[j])
            _assert_env_equal(A_got, A_ref)

    def test_out_indices_match_seed_structure(self):
        n = 6
        eng = _converged_system(n=n)
        T, W = eng.mps.tensors, eng.mpo
        A = left_edge(T[0], W[0])
        ref = extend_left(A, T[0], W[0], contract)
        assert env_out_indices(T[0], W[0], "left") == ref.indices
        B = right_edge(T[n - 1], W[n - 1])
        ref = extend_right(B, T[n - 1], W[n - 1], contract)
        assert env_out_indices(T[n - 1], W[n - 1], "right") == ref.indices

    def test_init_envs_match_seed_path(self):
        """_init_envs as a planned right-to-left pass == the seed rebuild."""
        n = 6
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(n // 2, 2, 1.0, 0.5, cylinder=False)
        mpo = compress_mpo(build_mpo(sp, terms, n), cutoff=1e-13)
        on = DMRGEngine(
            product_state_mps(sp, neel_states(sp, n)), mpo,
            davidson_iters=2, algo="list", jit_env=True,
        )
        off = DMRGEngine(
            product_state_mps(sp, neel_states(sp, n)), mpo,
            davidson_iters=2, algo="list", jit_env=False,
        )
        for e_on, e_off in zip(on.right_envs, off.right_envs):
            if e_on is None or e_off is None:
                assert e_on is e_off
                continue
            _assert_env_equal(e_on, e_off)


class TestCompileOnceEnv:
    def test_retraces_stop_growing_at_steady_state(self):
        """The padded fused core compiles during warmup and then replays:
        at structural steady state two further sweeps trigger zero new
        retraces (the compile-once contract of the env stage)."""
        eng = _converged_system(n=6, m=8, sweeps=0)
        eng.jit_env = True  # fused updates from here on
        # private plan cache: compiled cores live on the (normally global)
        # plans, so a shared cache warmed by earlier tests would hide the
        # compile this test wants to observe
        eng.contract_fn.env.cache = EnvPlanCache()
        for _ in range(4):
            eng.sweep(max_bond=8)
        env_eng = eng.contract_fn.env
        assert env_eng.jit_retraces > 0  # it did compile
        before = env_eng.jit_retraces
        for _ in range(2):
            eng.sweep(max_bond=8)
        assert env_eng.jit_retraces == before

    def test_plan_cache_hit_on_equal_structure(self):
        n = 6
        eng = _converged_system(n=n)
        T, W = eng.mps.tensors, eng.mpo
        ee = EnvironmentEngine(cache=EnvPlanCache())
        A = left_edge(T[0], W[0])
        ee.update_left(A, T[0], W[0])
        assert ee.cache.stats() == {"hits": 0, "misses": 1, "evictions": 0, "size": 1, "builds": 1}
        rt = ee.jit_retraces
        ee.update_left(A, T[0], W[0])
        assert ee.cache.stats() == {"hits": 1, "misses": 1, "evictions": 0, "size": 1, "builds": 1}
        assert ee.jit_retraces == rt  # compiled core reused, not retraced

    def test_left_and_right_have_distinct_plans(self):
        """Sweep direction is part of the composite signature."""
        n = 6
        eng = _converged_system(n=n)
        T, W = eng.mps.tensors, eng.mpo
        ee = EnvironmentEngine(cache=EnvPlanCache())
        # an env structure that is valid for both directions only exists at
        # the edges; check the two signatures never collide in the cache
        ee.update_left(left_edge(T[0], W[0]), T[0], W[0])
        ee.update_right(right_edge(T[n - 1], W[n - 1]), T[n - 1], W[n - 1])
        assert ee.cache.stats()["misses"] == 2
        assert ee.cache.stats()["size"] == 2


class TestSweepIntegration:
    @pytest.mark.x64
    def test_jit_env_energy_equals_seed(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=2, davidson_iters=6)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        fused = run_dmrg(sp, terms, 6, algo="list", jit_env=True, **kw)
        assert abs(seed.energy - fused.energy) < 1e-10

    @pytest.mark.x64
    def test_jit_env_on_off_agree(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4)
        on = run_dmrg(sp, terms, 6, algo="batched", jit_env=True, **kw)
        off = run_dmrg(sp, terms, 6, algo="batched", jit_env=False, **kw)
        assert abs(on.energy - off.energy) < 1e-10

    def test_env_seconds_stage_split_populated(self):
        eng = _converged_system(n=6, sweeps=0)
        eng.jit_env = True
        s = eng.sweep(max_bond=8)
        assert s.env_seconds > 0
        assert s.env_seconds < s.seconds
        ledger = eng.contract_fn.stats()["env"]
        # one update per pair optimization: 2 * (n - 1) per sweep
        assert ledger["env_updates"] == 2 * (6 - 1)
        assert ledger["env_flops"] > 0
        assert ledger["env_seconds"] > 0

    def test_jit_env_rejected_for_bare_contractors(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        mpo = compress_mpo(build_mpo(sp, terms, 6), cutoff=1e-13)
        mps = product_state_mps(sp, neel_states(sp, 6))
        with pytest.raises(ValueError, match="jit_env requires"):
            DMRGEngine(mps, mpo, algo="list_unplanned", jit_env=True)
        # and default resolves to off (no error, seed path) for bare algos
        eng = DMRGEngine(mps, mpo, algo="list_unplanned")
        assert eng.jit_env is False
