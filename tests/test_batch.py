"""Shape-bucketed batched backend, compile-once padding, and the batched
Davidson update: equality with the list backend block-for-block, retrace
accounting, and the zero-fill / error paths of the block-gemm packer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import run_dmrg
from repro.core.davidson import davidson
from repro.core.models import heisenberg_j1j2_terms
from repro.core.siteops import spin_half_space
from repro.dist import ContractionEngine, PlanCache
from repro.dist.batch import (
    bucket_dim,
    matricize_lhs,
    matricize_rhs,
    pad_block_sparse,
    pad_index,
    unpad_block_sparse,
)
from repro.kernels.block_gemm.ops import block_sparse_matmul, pack_pairs
from repro.tensor import BlockSparseTensor, Index, OUT, contract

from test_dist import AX, rand_index, rand_pair


class TestBatchedBackend:
    """Batched == list block-for-block across random charge structures."""

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), nq=st.integers(1, 2))
    def test_property_equals_list(self, seed, nq):
        A, B = rand_pair(seed, nq=nq)
        eng = ContractionEngine(backend="batched", cache=PlanCache())
        got, ref = eng(A, B, AX), contract(A, B, AX)
        assert set(got.blocks) == set(ref.blocks)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=1e-13
            )

    def test_higher_order_and_jit(self):
        rng = np.random.default_rng(3)
        i1, i2, i3 = (rand_index(rng) for _ in range(3))
        A = BlockSparseTensor.random([i1, i2, i3], key=jax.random.PRNGKey(0))
        B = BlockSparseTensor.random(
            [i2.dual(), i3.dual(), i1], key=jax.random.PRNGKey(1)
        )
        ax = ((1, 2), (0, 1))
        ref = contract(A, B, axes=ax).to_dense()
        eng = ContractionEngine(backend="batched", cache=PlanCache())
        np.testing.assert_allclose(
            np.asarray(eng(A, B, ax).to_dense()), np.asarray(ref), atol=1e-12
        )
        jf = jax.jit(lambda a, b: eng(a, b, ax))
        np.testing.assert_allclose(
            np.asarray(jf(A, B).to_dense()), np.asarray(ref), atol=1e-12
        )

    def test_bucket_table_covers_pairs(self):
        from repro.dist.plan import ContractionPlan

        A, B = rand_pair(11)
        plan = ContractionPlan.build(A, B, AX)
        L = plan.batched
        total = sum(len(b.oi) for b in L.buckets)
        assert total == plan.num_pairs
        # every bucket's blocks matricize to exactly the bucket shape
        for b in L.buckets:
            for ka in b.a_keys:
                r, c = matricize_lhs(A, plan.keep_a, plan.ax_a)[ka].shape
                assert (r, c) == (b.m, b.k)
            for kb in b.b_keys:
                r, c = matricize_rhs(B, plan.keep_b, plan.ax_b)[kb].shape
                assert (r, c) == (b.k, b.n)
            assert list(b.oi) == sorted(b.oi)

    def test_precomputed_mats_match_live(self):
        A, B = rand_pair(5)
        eng = ContractionEngine(backend="batched", cache=PlanCache())
        plan = eng.cache.get(A, B, AX)
        mats_a = matricize_lhs(A, plan.keep_a, plan.ax_a)
        mats_b = matricize_rhs(B, plan.keep_b, plan.ax_b)
        got = eng(A, B, AX, a_mats=mats_a, b_mats=mats_b)
        ref = eng(A, B, AX)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=0
            )


class TestPadding:
    def test_bucket_dim_powers_of_two(self):
        assert [bucket_dim(d) for d in (1, 2, 3, 4, 5, 9, 17)] == [
            1, 2, 4, 4, 8, 16, 32,
        ]

    def test_pad_unpad_roundtrip(self):
        A, _ = rand_pair(7)
        padded = pad_block_sparse(A)
        padded.check()
        back = unpad_block_sparse(padded, A.indices)
        assert back.indices == A.indices
        assert set(back.blocks) == set(A.blocks)
        for k in A.blocks:
            np.testing.assert_allclose(
                np.asarray(back.blocks[k]), np.asarray(A.blocks[k]), atol=0
            )

    def test_dims_differing_within_bucket_pad_equal(self):
        """The compile-once property: structures that differ only by a
        sector dim inside one bucket become identical after padding."""
        ix13 = Index((((0,), 13), ((2,), 5)), OUT)
        ix14 = Index((((0,), 14), ((2,), 6)), OUT)
        assert pad_index(ix13) == pad_index(ix14)  # both -> ((0,),16),((2,),8)

    def test_padded_contraction_equals_padding_of_contraction(self):
        A, B = rand_pair(9)
        ref = contract(A, B, AX)
        Ap, Bp = pad_block_sparse(A), pad_block_sparse(B)
        got = unpad_block_sparse(contract(Ap, Bp, AX), ref.indices)
        assert set(got.blocks) == set(ref.blocks)
        for k in ref.blocks:
            np.testing.assert_allclose(
                np.asarray(got.blocks[k]), np.asarray(ref.blocks[k]), atol=1e-13
            )


class TestCompileOnceMatvec:
    def _system(self):
        sp = spin_half_space()
        terms = heisenberg_j1j2_terms(3, 2, 1.0, 0.5, cylinder=False)
        return sp, terms

    @pytest.mark.x64
    def test_batched_energy_equals_seed(self):
        sp, terms = self._system()
        kw = dict(bond_schedule=(8, 16), sweeps_per_bond=2, davidson_iters=6)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        batched = run_dmrg(sp, terms, 6, algo="batched", **kw)
        assert abs(seed.energy - batched.energy) < 1e-10

    @pytest.mark.x64
    def test_batched_jit_pad_energy_equals_seed(self):
        sp, terms = self._system()
        kw = dict(bond_schedule=(8,), sweeps_per_bond=2, davidson_iters=4)
        seed = run_dmrg(sp, terms, 6, algo="list_unplanned", **kw)
        jit = run_dmrg(sp, terms, 6, algo="batched", jit_matvec=True, **kw)
        assert abs(seed.energy - jit.energy) < 1e-10

    def test_matvec_stops_retracing_after_warmup(self):
        """The bucketed jitted matvec compiles during warmup sweeps and then
        replays: once the block structure reaches steady state, a whole
        sweep triggers zero retraces."""
        from repro.core.mpo import build_mpo, compress_mpo
        from repro.core.mps import neel_states, product_state_mps
        from repro.core.sweep import DMRGEngine

        sp, terms = self._system()
        mpo = compress_mpo(build_mpo(sp, terms, 6), cutoff=1e-13)
        mps = product_state_mps(sp, neel_states(sp, 6))
        eng = DMRGEngine(mps, mpo, algo="batched", jit_matvec=True,
                         davidson_iters=2)
        for _ in range(4):
            eng.sweep(max_bond=8)
        assert eng.contract_fn.jit_retraces > 0  # it did compile
        before = eng.contract_fn.jit_retraces
        eng.sweep(max_bond=8)
        assert eng.contract_fn.jit_retraces == before  # compile-once reached


class TestEngineStats:
    def test_per_backend_counters(self):
        A, B = rand_pair(2)
        eng = ContractionEngine(backend="batched", cache=PlanCache())
        eng(A, B, AX)
        st_ = eng.stats()
        assert st_["backend_counts"]["batched"] == 1
        assert st_["backend_flops"]["batched"] > 0
        assert st_["backend_seconds"]["batched"] > 0
        assert st_["jit_retraces"] == 0
        assert st_["backend_counts"]["list"] == 0

    def test_auto_includes_batched_candidate(self):
        A, B = rand_pair(2)
        eng = ContractionEngine(backend="auto", cache=PlanCache())
        plan = eng.cache.get(A, B, AX)
        assert eng.choose_backend(plan) in ("list", "dense", "batched")
        # with free dispatch, exact-flop backends win; with huge dispatch
        # cost, the bucketed backend must beat per-pair list dispatch
        expensive = ContractionEngine(
            backend="auto", cache=PlanCache(), pair_overhead=1e12
        )
        choice = expensive.choose_backend(plan)
        L = plan.batched
        if plan.num_pairs > 0.5 * L.num_unique + 2 * L.num_buckets + 0.25 * L.num_out_slots:
            assert choice != "list"


class TestDevIdxPerMesh:
    def test_dev_idx_keyed_per_policy_mesh(self):
        from repro.dist import BlockShardPolicy, make_block_mesh

        A, B = rand_pair(4)
        cache = PlanCache()
        eng = ContractionEngine(backend="batched", cache=cache)
        eng(A, B, AX)
        plan = cache.get(A, B, AX)
        assert set(plan.batched.dev_idx) == {None}
        policy = BlockShardPolicy(make_block_mesh(devices=jax.devices()[:1]))
        eng.policy = policy
        eng(A, B, AX)
        assert set(plan.batched.dev_idx) == {None, policy.mesh}


class TestPackPairsZeroFill:
    def test_out_of_range_raises(self):
        with pytest.raises(ValueError, match="output ids"):
            pack_pairs([(0, 0, 3)], 2)
        with pytest.raises(ValueError, match="empty"):
            pack_pairs([], 1)

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_uncovered_outputs_zero_filled(self, use_kernel):
        # 3 output slots, slot 1 has no contributing pair
        li, ri, oi = pack_pairs([(0, 0, 0), (1, 1, 2), (0, 1, 2)], 3)
        rng = np.random.default_rng(0)
        lhs = jnp.asarray(rng.normal(size=(3, 4, 5)))
        rhs = jnp.asarray(rng.normal(size=(3, 5, 6)))
        out = block_sparse_matmul(
            lhs[li], rhs[ri], oi, 3, use_kernel=use_kernel, interpret=True
        )
        assert out.shape == (3, 4, 6)
        np.testing.assert_allclose(np.asarray(out[1]), 0.0, atol=0)
        np.testing.assert_allclose(
            np.asarray(out[0]), np.asarray(lhs[0] @ rhs[0]), atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(out[2]),
            np.asarray(lhs[1] @ rhs[1] + lhs[0] @ rhs[1]),
            atol=1e-12,
        )


class TestBatchedSubspaceDavidson:
    @pytest.mark.x64
    def test_matches_dense_eigensolver(self):
        """Gram-identity residual + fused column fetch reproduce the seed
        Davidson behavior: converges to the exact smallest eigenvalue."""
        ix = Index((((0,), 8),), OUT)  # single charge sector, dim 8
        H = BlockSparseTensor.random(
            [ix, ix.dual()], key=jax.random.PRNGKey(0)
        )
        blk = H.blocks[(0, 0)]
        H_sym = BlockSparseTensor(
            H.indices, {(0, 0): 0.5 * (blk + blk.T)}, H.charge
        )

        def mv(x):
            return contract(H_sym, x, ((1,), (0,)))

        x0 = BlockSparseTensor.random([ix], key=jax.random.PRNGKey(7))
        # with 8 iterations the subspace spans the whole 8-dim space
        lam, x, info = davidson(mv, x0, n_iter=8, tol=1e-12)
        evals = np.linalg.eigvalsh(np.asarray(H_sym.to_dense()))
        assert abs(lam - evals[0]) < 1e-8
        # returned vector is normalized and satisfies the eigen equation
        r = mv(x) - x.scale(lam)
        assert float(np.asarray(r.norm())) < 1e-6
        assert abs(float(np.asarray(x.norm())) - 1.0) < 1e-12

    def test_zero_iterations(self):
        ix = rand_index(np.random.default_rng(2))
        H = BlockSparseTensor.random([ix, ix.dual()], key=jax.random.PRNGKey(1))

        def mv(x):
            return contract(H, x, ((1,), (0,)))

        x0 = BlockSparseTensor.random([ix], key=jax.random.PRNGKey(3))
        lam, x, _ = davidson(mv, x0, n_iter=0)
        xn = x0.scale(1.0 / x0.norm())
        want = float(np.real(np.asarray(xn.inner(mv(xn)))))
        assert abs(lam - want) < 1e-12
