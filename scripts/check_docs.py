"""Docs checks for CI: markdown link resolution + quickstart extraction.

Two modes:

  python scripts/check_docs.py --links README.md DESIGN.md ...
      Fails (exit 1) if any relative markdown link target in the given
      files does not exist on disk.  External links (http/https/mailto)
      and pure in-page anchors (#...) are skipped; a #fragment on a
      relative path is stripped before the existence check.

  python scripts/check_docs.py --extract <section> README.md
      Prints every fenced ``bash`` code block found under the given
      markdown heading (e.g. "Quickstart") until the next same-or-higher
      level heading — CI pipes this into bash to smoke-execute the
      commands the README actually shows.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links(paths):
    bad = []
    for p in paths:
        path = Path(p)
        text = path.read_text()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                bad.append(f"{p}: broken link -> {target}")
    for line in bad:
        print(line)
    print(f"checked {len(paths)} files: {'FAIL' if bad else 'ok'}")
    return 1 if bad else 0


def extract_section_bash(section, path):
    """Print bash code blocks under `## <section>` (any heading level)."""
    lines = Path(path).read_text().splitlines()
    level = None
    in_section = False
    in_block = False
    found = False
    for line in lines:
        m = re.match(r"^(#+)\s+(.*)$", line)
        if m and not in_block:
            if in_section and len(m.group(1)) <= level:
                break
            if m.group(2).strip().lower() == section.lower():
                in_section = True
                level = len(m.group(1))
            continue
        if not in_section:
            continue
        if line.strip().startswith("```"):
            lang = line.strip().lstrip("`").strip()
            if in_block:
                in_block = False
            elif lang in ("bash", "sh", ""):
                in_block = True
                found = True
            continue
        if in_block:
            print(line)
    if not found:
        print(f"echo 'no bash blocks under section {section!r} in {path}' && exit 1")
        return 1
    return 0


def main(argv):
    if len(argv) >= 2 and argv[0] == "--links":
        return check_links(argv[1:])
    if len(argv) == 3 and argv[0] == "--extract":
        return extract_section_bash(argv[1], argv[2])
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
